"""Dedicated unit tests for core/scheduler.py: the four policies, capacity
filtering, warm-affinity tie-breaks, and the resource-aware capability filter
(the paper's §8 future work)."""
import pytest

from repro.core import Scheduler, TaskEnvelope
from repro.core.scheduler import POLICIES


class FakeExecutor:
    """Scheduler-facing executor surface: accepting / can_run /
    free_capacity_for / has_warm / executor_id."""

    def __init__(self, eid, cap, warm=(), capabilities=("cpu",), accepting=True):
        self.executor_id = eid
        self._cap = cap
        self._warm = set(warm)
        self._capabilities = frozenset(capabilities)
        self._accepting = accepting

    def accepting(self):
        return self._accepting

    def can_run(self, env):
        return set(env.requirements) <= self._capabilities

    def free_capacity_for(self, env):
        return self._cap if self.can_run(env) else 0

    def has_warm(self, key):
        return key in self._warm


def _env(requirements=(), container="default", function_id="f"):
    return TaskEnvelope(
        task_id="t", function_id=function_id, payload=b"",
        container=container, requirements=tuple(requirements),
    )


# ---------------------------------------------------------------- policies
def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        Scheduler("fifo")
    for p in POLICIES:
        assert Scheduler(p).policy == p


def test_random_uniform_over_capable(seed=7):
    s = Scheduler("random", seed=seed)
    exs = [FakeExecutor("a", 1), FakeExecutor("b", 1), FakeExecutor("c", 1)]
    picks = {s.choose(exs, _env()).executor_id for _ in range(50)}
    assert picks == {"a", "b", "c"}  # every capable executor is reachable


def test_round_robin_cycles():
    s = Scheduler("round_robin")
    exs = [FakeExecutor("a", 1), FakeExecutor("b", 1)]
    picks = [s.choose(exs, _env()).executor_id for _ in range(4)]
    assert picks == ["a", "b", "a", "b"]


def test_least_loaded_picks_most_free():
    s = Scheduler("least_loaded")
    exs = [FakeExecutor("a", 1), FakeExecutor("b", 5)]
    assert s.choose(exs, _env()).executor_id == "b"


def test_warm_affinity_prefers_warm_holder():
    s = Scheduler("warm_affinity")
    exs = [FakeExecutor("a", 9), FakeExecutor("b", 1, warm=[("f", "default")])]
    assert s.choose(exs, _env()).executor_id == "b"


def test_warm_affinity_tie_break_by_capacity():
    s = Scheduler("warm_affinity")
    key = ("f", "default")
    exs = [
        FakeExecutor("a", 2, warm=[key]),
        FakeExecutor("b", 6, warm=[key]),   # warm AND most free: wins
        FakeExecutor("c", 9),               # more free but cold: loses
    ]
    assert s.choose(exs, _env()).executor_id == "b"


def test_warm_affinity_spills_to_cold_when_no_warm():
    s = Scheduler("warm_affinity")
    exs = [FakeExecutor("a", 2), FakeExecutor("b", 6)]
    assert s.choose(exs, _env(container="v2")).executor_id == "b"


# ---------------------------------------------------------------- filtering
def test_none_when_no_capacity():
    s = Scheduler("random")
    assert s.choose([FakeExecutor("a", 0)], _env()) is None


def test_not_accepting_excluded():
    s = Scheduler("least_loaded")
    exs = [FakeExecutor("a", 9, accepting=False), FakeExecutor("b", 1)]
    assert s.choose(exs, _env()).executor_id == "b"


def test_capability_filter_excludes_incapable():
    s = Scheduler("least_loaded")
    exs = [
        FakeExecutor("cpu", 9, capabilities=("cpu",)),
        FakeExecutor("tpu", 1, capabilities=("cpu", "tpu")),
    ]
    # the bigger executor can't run a tpu task: the filter removes it
    assert s.choose(exs, _env(requirements=("tpu",))).executor_id == "tpu"
    # requirement-free tasks still see every executor
    assert s.choose(exs, _env()).executor_id == "cpu"


def test_none_when_no_capable_executor():
    s = Scheduler("random")
    exs = [FakeExecutor("a", 9, capabilities=("cpu",))]
    assert s.choose(exs, _env(requirements=("gpu",))) is None
    assert s.capable(exs, _env(requirements=("gpu",))) == []


def test_capability_filter_runs_before_every_policy():
    task = _env(requirements=("tpu",))
    exs = [
        FakeExecutor("cpu1", 9),
        FakeExecutor("tpu1", 1, capabilities=("cpu", "tpu")),
        FakeExecutor("tpu2", 2, warm=[("f", "default")], capabilities=("cpu", "tpu")),
    ]
    for policy in POLICIES:
        chosen = Scheduler(policy, seed=0).choose(exs, task)
        assert chosen.executor_id in ("tpu1", "tpu2"), policy
