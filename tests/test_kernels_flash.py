"""Pallas flash-attention kernel vs the pure-jnp oracle: shape/dtype/causal/
GQA sweeps in interpret mode (assignment requirement: per-kernel allclose)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property-based cases skip without the dev extra
    from _hypothesis_stub import given, settings, st

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.kernel import (
    decode_attention_pallas,
    flash_attention_pallas,
)

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(key, shape, dt):
    return jax.random.normal(key, shape, dt)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Skv,H,KV,hd",
    [
        (1, 64, 64, 4, 4, 32),     # MHA
        (2, 128, 128, 8, 2, 64),   # GQA 4:1
        (1, 96, 96, 6, 1, 16),     # MQA, non-pow2 heads
        (1, 100, 132, 4, 2, 32),   # unaligned seq (padding path)
        (2, 32, 256, 4, 4, 64),    # Skv >> Sq
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(B, Sq, Skv, H, KV, hd, causal, dtype, key):
    if causal and Sq != Skv:
        pytest.skip("causal sweep uses square shapes")
    ks = jax.random.split(key, 3)
    q = _rand(ks[0], (B, Sq, H, hd), dtype)
    k = _rand(ks[1], (B, Skv, KV, hd), dtype)
    v = _rand(ks[2], (B, Skv, KV, hd), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=32, block_k=32,
                                 interpret=True)
    expected = ref.mha_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expected.astype(jnp.float32),
        rtol=TOL[dtype], atol=TOL[dtype],
    )


@pytest.mark.parametrize("block", [16, 64, 128])
def test_flash_block_shape_invariance(block, key):
    ks = jax.random.split(key, 3)
    q = _rand(ks[0], (1, 128, 4, 32), jnp.float32)
    k = _rand(ks[1], (1, 128, 4, 32), jnp.float32)
    v = _rand(ks[2], (1, 128, 4, 32), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=block, block_k=block,
                                 interpret=True)
    expected = ref.mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(out, expected, rtol=2e-5, atol=2e-5)


def test_flash_kv_len_masking(key):
    ks = jax.random.split(key, 3)
    q = _rand(ks[0], (1, 16, 2, 16), jnp.float32)
    k = _rand(ks[1], (1, 64, 2, 16), jnp.float32)
    v = _rand(ks[2], (1, 64, 2, 16), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=False, kv_len=jnp.int32(20),
                                 block_q=16, block_k=16, interpret=True)
    expected = ref.mha_reference(q, k, v, causal=False, kv_len=jnp.int32(20))
    np.testing.assert_allclose(out, expected, rtol=2e-5, atol=2e-5)


def test_flash_q_offset_decode_window(key):
    """q_offset shifts absolute positions (used when decoding a block of
    suffix tokens against a longer cache)."""
    ks = jax.random.split(key, 3)
    S = 64
    q_full = _rand(ks[0], (1, S, 2, 16), jnp.float32)
    k = _rand(ks[1], (1, S, 2, 16), jnp.float32)
    v = _rand(ks[2], (1, S, 2, 16), jnp.float32)
    full = ref.mha_reference(q_full, k, v, causal=True)
    tail = flash_attention_pallas(
        q_full[:, 48:], k, v, causal=True, q_offset=jnp.int32(48),
        block_q=16, block_k=16, interpret=True,
    )
    np.testing.assert_allclose(tail, full[:, 48:], rtol=2e-5, atol=2e-5)


@given(
    pos=st.integers(min_value=0, max_value=47),
    kv=st.sampled_from([1, 2, 4]),
)
@settings(max_examples=12, deadline=None)
def test_decode_kernel_property(pos, kv):
    key = jax.random.PRNGKey(pos)
    ks = jax.random.split(key, 3)
    B, S, H, hd = 2, 48, 4, 16
    q = _rand(ks[0], (B, 1, H, hd), jnp.float32)
    kc = _rand(ks[1], (B, S, kv, hd), jnp.float32)
    vc = _rand(ks[2], (B, S, kv, hd), jnp.float32)
    out = decode_attention_pallas(q, kc, vc, jnp.int32(pos), interpret=True)
    expected = ref.decode_attention_reference(q, kc, vc, jnp.int32(pos))
    np.testing.assert_allclose(out, expected, rtol=3e-5, atol=3e-5)


def test_decode_kernel_vector_positions(key):
    ks = jax.random.split(key, 3)
    B, S, KV, H, hd = 3, 32, 2, 4, 16
    q = _rand(ks[0], (B, 1, H, hd), jnp.float32)
    kc = _rand(ks[1], (B, S, KV, hd), jnp.float32)
    vc = _rand(ks[2], (B, S, KV, hd), jnp.float32)
    pos = jnp.array([3, 17, 31], jnp.int32)
    out = decode_attention_pallas(q, kc, vc, pos, interpret=True)
    expected = ref.decode_attention_reference(q, kc, vc, pos)
    np.testing.assert_allclose(out, expected, rtol=3e-5, atol=3e-5)


def test_causality_property(key):
    """Changing future keys/values must not change past outputs."""
    ks = jax.random.split(key, 4)
    q = _rand(ks[0], (1, 64, 2, 16), jnp.float32)
    k = _rand(ks[1], (1, 64, 2, 16), jnp.float32)
    v = _rand(ks[2], (1, 64, 2, 16), jnp.float32)
    out1 = flash_attention_pallas(q, k, v, causal=True, block_q=16, block_k=16,
                                  interpret=True)
    k2 = k.at[:, 40:].set(_rand(ks[3], (1, 24, 2, 16), jnp.float32))
    out2 = flash_attention_pallas(q, k2, v, causal=True, block_q=16, block_k=16,
                                  interpret=True)
    np.testing.assert_allclose(out1[:, :40], out2[:, :40], rtol=1e-6, atol=1e-6)
    assert not np.allclose(out1[:, 41:], out2[:, 41:])
