"""Policy-driven autoscaler: policy math, clamping, proportional steps,
cooldown anti-flapping, drain-before-scale-in, and the watchdog replacement
path's max_blocks ceiling."""
import queue
import time

import pytest

from repro.core import (
    Autoscaler,
    FunctionService,
    LatencySLOPolicy,
    Provider,
    ProviderSpec,
    ScalingObservation,
    TargetQueueDepthPolicy,
    make_policy,
)


# ---------------------------------------------------------------- fakes
class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


class FakeExecutor:
    def __init__(self, in_flight=0, queued=0):
        self.in_flight = {f"t{i}": object() for i in range(in_flight)}
        self.inbox = queue.Queue()
        for i in range(queued):
            self.inbox.put(object())
        self.suspend_calls = 0
        self.resume_calls = 0
        self.suspended = False

    def queued_tasks(self):
        return self.inbox.qsize()

    def suspend(self):
        self.suspend_calls += 1
        self.suspended = True

    def resume(self):
        self.resume_calls += 1
        self.suspended = False


class FakeProvider(Provider):
    """Counts blocks; honours max_blocks like LocalThreadProvider."""

    def __init__(self, spec):
        super().__init__(spec)
        self._counter = 0

    def scale_out(self, n):
        out = []
        for _ in range(n):
            if len(self._blocks) >= self.spec.max_blocks:
                break
            bid = f"b{self._counter}"
            self._counter += 1
            self._blocks[bid] = object()
            out.append(bid)
        return out

    def scale_in(self, block_ids):
        for bid in block_ids:
            self._blocks.pop(bid, None)


class FakeHost:
    def __init__(self, obs=None, idle_block=None):
        self.obs = obs or ScalingObservation()
        self.idle_block = idle_block  # (block_id, FakeExecutor) or None
        self.released = []

    def observe(self):
        return self.obs

    def select_idle_block(self):
        return self.idle_block

    def release_block(self, block_id):
        self.released.append(block_id)


def make_scaler(min_blocks=1, max_blocks=8, init=1, cooldown_s=5.0, **kw):
    provider = FakeProvider(
        ProviderSpec(min_blocks=min_blocks, max_blocks=max_blocks,
                     workers_per_block=2)
    )
    provider.scale_out(init)
    clock = FakeClock()
    host = kw.pop("host", FakeHost())
    scaler = Autoscaler(provider, host, cooldown_s=cooldown_s, clock=clock, **kw)
    return scaler, provider, host, clock


def obs(queue_depth=0, outstanding=0, blocks=1, wpb=2, p95=None):
    return ScalingObservation(
        queue_depth=queue_depth, outstanding=outstanding, blocks=blocks,
        workers_per_block=wpb, p95_latency_s=p95,
    )


# ---------------------------------------------------------------- policies
def test_queue_depth_policy_sizes_to_demand():
    pol = TargetQueueDepthPolicy(target_tasks_per_worker=2.0)
    assert pol.desired_blocks(obs(queue_depth=0, outstanding=0)) == 0
    # 16 tasks / 2-per-worker = 8 workers = 4 blocks of 2
    assert pol.desired_blocks(obs(queue_depth=12, outstanding=4)) == 4
    assert pol.desired_blocks(obs(queue_depth=1)) == 1  # never 0 under demand


def test_latency_slo_policy_reacts_to_p95():
    pol = LatencySLOPolicy(slo_s=1.0)
    assert pol.desired_blocks(obs(blocks=4, queue_depth=9, p95=2.0)) == 6  # breach: +50%
    assert pol.desired_blocks(obs(blocks=4, queue_depth=9, p95=0.5)) == 4  # in band: hold
    assert pol.desired_blocks(obs(blocks=4, queue_depth=9, p95=None)) == 4  # no signal: hold
    # idleness dominates the (frozen) latency window: drain even on a stale
    # breach sample, and from a no-signal state
    assert pol.desired_blocks(obs(blocks=4, p95=2.0)) == 3
    assert pol.desired_blocks(obs(blocks=4, p95=None)) == 3
    # bootstrap from zero blocks on demand alone
    assert pol.desired_blocks(obs(blocks=0, queue_depth=3)) == 1
    assert pol.desired_blocks(obs(blocks=0)) == 0


def test_make_policy_resolution():
    assert isinstance(make_policy("queue_depth"), TargetQueueDepthPolicy)
    assert isinstance(
        make_policy("latency_slo", latency_slo_s=0.5), LatencySLOPolicy
    )
    pol = TargetQueueDepthPolicy(1.0)
    assert make_policy(pol) is pol
    with pytest.raises(ValueError):
        make_policy("nope")


# ---------------------------------------------------------------- scale out
def test_scale_out_proportional_steps_converge():
    scaler, provider, host, clock = make_scaler(init=1, max_blocks=8)
    # demand wants 5 blocks; step_fraction=0.5 climbs 1 -> 3 -> 4 -> 5
    load = obs(queue_depth=20, outstanding=0)
    sizes = []
    for _ in range(4):
        scaler.tick(load)
        sizes.append(len(provider._blocks))
        clock.advance(0.1)
    assert sizes == [3, 4, 5, 5]


def test_scale_out_clamped_to_max_blocks():
    scaler, provider, host, clock = make_scaler(init=1, max_blocks=3)
    for _ in range(5):
        scaler.tick(obs(queue_depth=100))
        clock.advance(0.1)
    assert len(provider._blocks) == 3


# ---------------------------------------------------------------- scale in
def test_scale_in_waits_for_cooldown_then_drains_idle():
    ex = FakeExecutor()
    scaler, provider, host, clock = make_scaler(
        init=1, cooldown_s=5.0, host=FakeHost(idle_block=("b9", FakeExecutor()))
    )
    scaler.tick(obs(queue_depth=20))          # scale out: cooldown timer arms
    host.idle_block = ("b0", ex)
    d = scaler.tick(obs())                    # idle, but inside cooldown
    assert d.action == "hold" and d.reason == "cooldown"
    clock.advance(6.0)
    d = scaler.tick(obs())
    assert d.action == "scale_in"
    assert ex.suspend_calls == 1
    assert host.released == ["b0"]


def test_scale_in_never_drops_below_min_blocks():
    ex = FakeExecutor()
    scaler, provider, host, clock = make_scaler(
        init=2, min_blocks=2, cooldown_s=0.0, host=FakeHost(idle_block=("b0", ex))
    )
    for _ in range(5):
        d = scaler.tick(obs(blocks=2))
        clock.advance(1.0)
    assert d.action == "hold"
    assert len(provider._blocks) == 2
    assert host.released == []


def test_scale_in_never_kills_executor_with_outstanding_tasks():
    busy = FakeExecutor(in_flight=2)
    scaler, provider, host, clock = make_scaler(
        init=2, cooldown_s=0.0, host=FakeHost(idle_block=("b1", busy))
    )
    clock.advance(1.0)
    d = scaler.tick(obs(blocks=2))            # desired 1 < current 2
    # drain attempt found work after suspension: resumed, nothing released
    assert d.action == "hold" and "no idle block" in d.reason
    assert busy.suspend_calls == 1 and busy.resume_calls == 1
    assert not busy.suspended
    assert host.released == []
    assert len(provider._blocks) == 2


def test_cooldown_prevents_flapping_under_oscillating_load():
    idle_ex = FakeExecutor()
    scaler, provider, host, clock = make_scaler(
        init=1, cooldown_s=10.0, host=FakeHost(idle_block=("b0", idle_ex))
    )
    # load flips every 0.5s; every burst re-arms the cooldown, so the quiet
    # half-periods never produce a scale-in
    for i in range(20):
        scaler.tick(obs(queue_depth=20 if i % 2 == 0 else 0))
        clock.advance(0.5)
    assert scaler.scale_in_events == 0
    assert scaler.scale_out_events >= 1
    # sustained quiet past the cooldown finally drains
    clock.advance(11.0)
    scaler.tick(obs())
    assert scaler.scale_in_events == 1


# ---------------------------------------------------------------- replacement
def test_replace_block_releases_corpse_and_respects_ceiling():
    scaler, provider, host, clock = make_scaler(init=3, max_blocks=3)
    # dead block released first, so the replacement fits under the ceiling
    assert scaler.replace_block("b0") is True
    assert len(provider._blocks) == 3
    assert scaler.replacements == 1
    # at the ceiling with no corpse to release: denied, never exceeds max
    assert scaler.replace_block(None) is False
    assert len(provider._blocks) == 3
    assert scaler.ceiling_denials == 1


def test_repeated_failures_never_exceed_max_blocks():
    scaler, provider, host, clock = make_scaler(init=2, max_blocks=2)
    for i in range(6):
        bid = next(iter(provider._blocks))
        scaler.replace_block(bid)
        assert len(provider._blocks) <= 2
    assert len(provider._blocks) == 2


# ---------------------------------------------------------------- integration
def _sleepy(doc):
    time.sleep(doc.get("t", 0.01))
    return {"i": doc.get("i", -1)}


def test_endpoint_scales_out_under_burst_and_back_to_min():
    svc = FunctionService()
    ep = svc.make_endpoint(
        "burst", n_executors=1, workers_per_executor=2, max_executors=4,
        elastic=True, heartbeat_interval_s=0.05, scale_cooldown_s=0.2,
    )
    fid = svc.register_function(_sleepy)
    futs = [svc.run(fid, {"i": i, "t": 0.02}) for i in range(60)]
    results = [f.result(30) for f in futs]
    assert sorted(r["i"] for r in results) == list(range(60))
    assert ep.autoscaler.scale_out_events >= 1, "burst must trigger scale-out"
    # quiet: blocks drain back to min_blocks, one per tick after cooldown
    deadline = time.monotonic() + 10
    while time.monotonic() < deadline:
        if len(ep.executors) == ep.autoscaler.min_blocks:
            break
        time.sleep(0.02)
    assert len(ep.executors) == ep.autoscaler.min_blocks == 1
    assert ep.autoscaler.scale_in_events >= 1
    # scale-in lost nothing: every completed task already delivered above
    assert ep.completed >= 60
    svc.shutdown()


def test_endpoint_scale_in_skips_busy_executors():
    svc = FunctionService()
    # non-elastic: the manager loop never ticks the autoscaler, so the test
    # drives scale-in decisions deterministically by hand
    ep = svc.make_endpoint(
        "busy", n_executors=2, workers_per_executor=1, max_executors=2,
        heartbeat_interval_s=0.05, scale_cooldown_s=0.0,
    )
    fid = svc.register_function(_sleepy)
    # occupy both executors with long tasks, then force a scale-in decision
    futs = [svc.run(fid, {"i": i, "t": 0.6}) for i in range(2)]
    time.sleep(0.2)  # both dispatched and running
    d = ep.autoscaler.tick(ScalingObservation(blocks=2, workers_per_block=1))
    assert d.action == "hold"  # no idle block: busy executors are never killed
    assert len(ep.executors) == 2
    results = [f.result(20) for f in futs]
    assert sorted(r["i"] for r in results) == [0, 1]
    # once drained and idle, the same decision does scale in
    deadline = time.monotonic() + 5
    while time.monotonic() < deadline and len(ep.executors) > 1:
        ep.autoscaler.tick(ScalingObservation(blocks=2, workers_per_block=1))
        time.sleep(0.02)
    assert len(ep.executors) == 1
    svc.shutdown()
