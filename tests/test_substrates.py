"""Optimizer, checkpointer, partitioner, MoE dispatch, data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property-based cases skip without the dev extra
    from _hypothesis_stub import given, settings, st

from repro.configs import get_reduced
from repro.configs.base import MoEConfig
from repro.data.pipeline import Prefetcher, synthetic_batch

from repro.checkpoint.checkpointer import Checkpointer
from repro.models import moe as moe_mod
from repro.sharding import partition
from repro.training import optimizer as opt


# ---------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.array([2.0, -3.0], jnp.float32)}
    state = opt.init_state(params)
    cfg = opt.OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=100,
                              weight_decay=0.0, clip_norm=100.0)
    dtypes = jax.tree.map(lambda p: p.dtype, params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = loss(params)
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state = opt.apply_updates(g, state, cfg, dtypes)
    assert loss(params) < l0 * 0.01


def test_grad_clip_applies():
    params = {"w": jnp.zeros(3)}
    state = opt.init_state(params)
    cfg = opt.OptimizerConfig(lr=1.0, warmup_steps=0, clip_norm=1e-3,
                              weight_decay=0.0)
    dtypes = jax.tree.map(lambda p: p.dtype, params)
    g = {"w": jnp.full(3, 1e6)}
    new_params, _ = opt.apply_updates(g, state, cfg, dtypes)
    # clipped: the update magnitude is bounded by ~lr even with a huge grad
    assert float(jnp.max(jnp.abs(new_params["w"]))) < 10.0


def test_schedule_warmup_and_decay():
    cfg = opt.OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_ratio=0.1)
    assert float(opt.schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(opt.schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert float(opt.schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, abs=1e-6)


def test_bf16_params_keep_fp32_master():
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = opt.init_state(params)
    assert state["master"]["w"].dtype == jnp.float32
    cfg = opt.OptimizerConfig(lr=1e-4, warmup_steps=0)
    dtypes = jax.tree.map(lambda p: p.dtype, params)
    new_params, new_state = opt.apply_updates({"w": jnp.ones(4)}, state, cfg, dtypes)
    assert new_params["w"].dtype == jnp.bfloat16
    assert new_state["master"]["w"].dtype == jnp.float32


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    tree = {"a": np.arange(6).reshape(2, 3).astype(np.float32),
            "b": {"c": np.float32(3.5), "d": np.arange(4, dtype=np.int64)}}
    ck.save(5, tree)
    ck.save(10, tree)
    ck.save(15, tree)
    assert ck.list_steps() == [10, 15]  # keep=2 garbage-collected step 5
    step, restored = ck.restore(tree)
    assert step == 15
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["d"], tree["b"]["d"])


def test_checkpoint_async_save_then_restore(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=True)
    tree = {"w": np.ones((8, 8), np.float32) * 7}
    ck.save(1, tree)
    ck.wait()
    step, restored = ck.restore(tree)
    assert step == 1 and float(restored["w"][0, 0]) == 7


def test_checkpoint_structure_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, {"a": np.ones(2)})
    with pytest.raises(ValueError):
        ck.restore({"a": np.ones(2), "b": np.ones(2)})


# ---------------------------------------------------------------- partition
def _mesh(shape, axes):
    devs = np.array(jax.devices()[:1] * int(np.prod(shape))).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def test_resolve_spec_divisibility_fallback():
    mesh = _mesh((1, 1), ("data", "model"))
    # single-device axes -> everything replicates
    ctx = partition.MeshContext(mesh, partition.DEFAULT_RULES)
    spec = partition.resolve_spec(("embed", "mlp"), (64, 128), ctx)
    assert spec == jax.sharding.PartitionSpec()


def test_resolve_spec_greedy_no_axis_reuse():
    import jax.sharding as shd
    devs = np.array(jax.devices() * 8)[:8].reshape(2, 4)
    mesh = shd.Mesh(devs, ("data", "model"))
    ctx = partition.MeshContext(mesh, partition.DEFAULT_RULES)
    # experts divisible by model(4): takes it; mlp then can't reuse model
    spec = partition.resolve_spec(("experts", "embed", "mlp"), (8, 64, 128), ctx)
    assert spec == shd.PartitionSpec("model", "data")
    # experts NOT divisible -> TP-MoE fallback: mlp gets the model axis
    spec2 = partition.resolve_spec(("experts", "embed", "mlp"), (6, 64, 128), ctx)
    assert spec2 == shd.PartitionSpec(None, "data", "model")


def test_resolve_spec_no_mesh_is_noop():
    assert partition.resolve_spec(("batch", "seq"), (4, 4), None) == \
        jax.sharding.PartitionSpec()


# ------------------------------------------------------------------- MoE
def moe_dense_oracle(x2d, p, m: MoEConfig):
    """Per-token loop: every token runs its top-k experts exactly (no
    capacity). Ground truth for the gather/scatter dispatch."""
    topw, topi, _ = moe_mod.route(x2d, p["router"], m)
    outs = []
    for t in range(x2d.shape[0]):
        acc = jnp.zeros(x2d.shape[1], jnp.float32)
        for j in range(m.top_k):
            e = int(topi[t, j])
            h = x2d[t] @ p["wi"][e]
            g = x2d[t] @ p["wg"][e]
            y = (h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)) @ p["wo"][e]
            acc = acc + float(topw[t, j]) * y.astype(jnp.float32)
        outs.append(acc)
    return jnp.stack(outs)


def test_moe_dispatch_matches_dense_oracle(key):
    cfg = get_reduced("qwen3-moe-235b-a22b").with_(dtype="float32")
    m = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, capacity_factor=8.0)
    cfg = cfg.with_(moe=m, d_model=8)
    p, _ = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(key, (1, 12, 8), jnp.float32)
    out, aux = moe_mod.moe_ffn(x, p, cfg)
    oracle = moe_dense_oracle(x.reshape(12, 8), p, m)
    np.testing.assert_allclose(out.reshape(12, 8), oracle, rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens_not_correctness(key):
    """With capacity_factor tiny, overflow tokens are dropped (output 0 from
    routed experts) but the op still runs and keeps shapes."""
    cfg = get_reduced("qwen3-moe-235b-a22b").with_(dtype="float32", d_model=8)
    m = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, capacity_factor=0.1)
    cfg = cfg.with_(moe=m)
    p, _ = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(key, (1, 32, 8), jnp.float32)
    out, _ = moe_mod.moe_ffn(x, p, cfg)
    assert out.shape == (1, 32, 8)
    assert bool(jnp.all(jnp.isfinite(out)))


@given(st.integers(2, 6), st.integers(1, 3), st.integers(8, 40))
@settings(max_examples=15, deadline=None)
def test_moe_dispatch_conservation_property(E, k, T):
    """Every kept (token, expert) slot appears at most once and combine
    weights of dropped slots are zero."""
    k = min(k, E)
    key = jax.random.PRNGKey(E * 100 + k * 10 + T)
    topi_raw = jax.random.randint(key, (T, k * 3), 0, E)[:, :k]
    # make per-token experts distinct by construction
    topi = jnp.stack([(topi_raw[:, 0] + j) % E for j in range(k)], axis=1)
    topw = jnp.full((T, k), 1.0 / k)
    m = MoEConfig(n_experts=E, top_k=k, d_ff_expert=8, capacity_factor=1.0)
    gather_idx, combine_w, C, assign_slot = moe_mod.build_dispatch(topi, topw, T, m)
    assert gather_idx.shape == (E * C,)
    used = np.asarray(gather_idx).reshape(E, C)
    w = np.asarray(combine_w).reshape(E, C)
    # dropped slots point at the padding row T with zero weight
    assert np.all(w[used == T] == 0.0)
    for e in range(E):
        toks = used[e][used[e] < T]
        assert len(set(toks.tolist())) == len(toks)  # no dup within an expert
        # only tokens that actually routed to e occupy its slots
        routed = set(np.argwhere(np.asarray(topi) == e)[:, 0].tolist())
        assert set(toks.tolist()) <= routed


# ---------------------------------------------------------------- pipeline
def test_synthetic_batches_deterministic():
    cfg = get_reduced("qwen1.5-0.5b")
    a = synthetic_batch(cfg, 2, 16, step=7)
    b = synthetic_batch(cfg, 2, 16, step=7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_batch(cfg, 2, 16, step=8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_prefetcher_preserves_order_and_closes():
    it = iter(range(50))
    pf = Prefetcher(it, depth=4, transform=lambda x: x * 2)
    got = [next(pf) for _ in range(20)]
    assert got == [2 * i for i in range(20)]
    pf.close()


def test_prefetcher_propagates_exceptions():
    def gen():
        yield 1
        raise RuntimeError("source died")

    pf = Prefetcher(gen(), depth=2)
    assert next(pf) == 1
    with pytest.raises((RuntimeError, StopIteration)):
        next(pf)
        next(pf)


def test_moe_gather_combine_equals_scatter(key):
    cfg = get_reduced("qwen3-moe-235b-a22b").with_(dtype="float32", d_model=8)
    m = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, capacity_factor=8.0)
    p, _ = moe_mod.init_moe(key, cfg.with_(moe=m))
    x = jax.random.normal(key, (2, 12, 8), jnp.float32)
    ys, _ = moe_mod.moe_ffn(x, p, cfg.with_(moe=m, moe_combine="scatter"))
    yg, _ = moe_mod.moe_ffn(x, p, cfg.with_(moe=m, moe_combine="gather"))
    np.testing.assert_allclose(ys, yg, rtol=1e-5, atol=1e-5)
    # and with capacity drops: both modes drop the SAME assignments
    m2 = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, capacity_factor=0.5)
    ys2, _ = moe_mod.moe_ffn(x, p, cfg.with_(moe=m2, moe_combine="scatter"))
    yg2, _ = moe_mod.moe_ffn(x, p, cfg.with_(moe=m2, moe_combine="gather"))
    np.testing.assert_allclose(ys2, yg2, rtol=1e-5, atol=1e-5)


def test_pure_dp_rules_widen_batch():
    from repro.configs import get_config

    cfg = get_config("deepseek-67b").with_(pure_dp=True)
    rules = partition.rules_for(cfg)
    assert ("data", "model") in rules["batch"]
    # default rules untouched
    base = partition.rules_for(get_config("deepseek-67b"))
    assert base["batch"] == partition.DEFAULT_RULES["batch"]


def test_local_moe_respects_local_capacity(key):
    """The shard_map-local dispatch ranks within local experts only; on a
    single device (n_local == n_experts, base 0) it matches the global path."""
    cfg = get_reduced("qwen3-moe-235b-a22b").with_(dtype="float32", d_model=8)
    m = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, capacity_factor=8.0)
    p, _ = moe_mod.init_moe(key, cfg.with_(moe=m))
    x = jax.random.normal(key, (12, 8), jnp.float32)
    y_local, aux_local = moe_mod._local_expert_ffn(x, p, m, 0, m.n_experts)
    y_global, aux_global = moe_mod.moe_ffn(
        x[None], p, cfg.with_(moe=m, moe_combine="scatter")
    )
    np.testing.assert_allclose(y_local, y_global[0], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(aux_local, aux_global, rtol=1e-5)
