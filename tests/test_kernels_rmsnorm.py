"""Pallas fused add+RMSNorm kernel vs pure-jnp oracle (shape/dtype sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rmsnorm.kernel import fused_add_rmsnorm_pallas
from repro.kernels.rmsnorm.ref import fused_add_rmsnorm_reference

TOL = {jnp.float32: 1e-6, jnp.bfloat16: 1e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 32, 64), (2, 100, 128), (1, 8, 256), (7, 96)])
@pytest.mark.parametrize("block_rows", [8, 64])
def test_fused_add_rmsnorm_matches_ref(shape, dtype, block_rows, key):
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], shape, dtype)
    d = jax.random.normal(ks[1], shape, dtype)
    scale = jnp.abs(jax.random.normal(ks[2], (shape[-1],), jnp.float32)) + 0.5
    res_k, out_k = fused_add_rmsnorm_pallas(x, d, scale, block_rows=block_rows,
                                            interpret=True)
    res_r, out_r = fused_add_rmsnorm_reference(x, d, scale)
    tol = TOL[dtype]
    np.testing.assert_allclose(res_k.astype(jnp.float32), res_r.astype(jnp.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(out_k.astype(jnp.float32), out_r.astype(jnp.float32),
                               rtol=tol, atol=tol)


def test_fused_matches_model_rmsnorm(key):
    """The fused ref must equal models.layers.rmsnorm on (x + delta)."""
    from repro.models import layers

    x = jax.random.normal(key, (2, 16, 32), jnp.float32)
    d = jax.random.normal(jax.random.split(key)[0], (2, 16, 32), jnp.float32)
    scale = jnp.ones((32,), jnp.float32) * 1.3
    _, out = fused_add_rmsnorm_reference(x, d, scale, eps=1e-5)
    expected = layers.rmsnorm(x + d, {"scale": scale}, eps=1e-5)
    np.testing.assert_allclose(out, expected, rtol=1e-6, atol=1e-6)
