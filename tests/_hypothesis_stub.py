"""Fallback shims for environments without `hypothesis`.

Importing ``given``/``settings``/``st`` from here keeps modules that define
property-based tests collectable on a clean environment: strategy
construction becomes a no-op and each ``@given`` test is skipped with a
clear reason. Install the real thing via the ``dev`` extra
(``pip install -e ".[dev]"``) to run the property-based cases.
"""
import pytest


class _AnyStrategy:
    """Absorbs any strategy-building call chain (st.lists(st.integers(...)),
    .map(...), .filter(...), ...) and returns itself."""

    def __getattr__(self, name):
        return self

    def __call__(self, *args, **kwargs):
        return self


st = _AnyStrategy()


def given(*args, **kwargs):
    return lambda fn: pytest.mark.skip(reason="hypothesis not installed")(fn)


def settings(*args, **kwargs):
    return lambda fn: fn
