"""Heterogeneous container fabric: typed worker pools + capability-aware
routing end to end (paper §5.3–5.4 container management, §8 resource-aware
scheduling)."""
import gc
import queue
import time
import weakref

import pytest

from repro.core import (
    CapabilityError,
    ContainerPool,
    ContainerSpec,
    FunctionRegistry,
    FunctionService,
    Invocation,
    ResourceSpec,
    WarmPool,
    default_container_spec,
)


def _echo(doc):
    return doc


def _accel_spec(max_workers=2, name="accel"):
    return ContainerSpec(
        name=name, capabilities=frozenset({"cpu", "accel"}),
        min_workers=0, max_workers=max_workers,
    )


# ---------------------------------------------------------------- specs
def test_container_spec_validation():
    with pytest.raises(ValueError):
        ContainerSpec(name="bad", max_workers=0)
    with pytest.raises(ValueError):
        ContainerSpec(name="bad", min_workers=5, max_workers=2)
    spec = ContainerSpec(name="tpu", capabilities="tpu")  # lone string = 1 cap
    assert spec.capabilities == frozenset({"tpu"})
    assert spec.provides(()) and spec.provides({"tpu"})
    assert not spec.provides({"tpu", "gpu"})


def test_resource_spec_satisfied_by():
    spec = ResourceSpec(capabilities=("tpu", "cpu"))
    assert spec.satisfied_by({"tpu", "cpu", "extra"})
    assert not spec.satisfied_by({"cpu"})
    assert ResourceSpec().satisfied_by(())  # requirement-free runs anywhere


# ---------------------------------------------------------------- pools
def _make_pool(spec):
    reg = FunctionRegistry()
    outbox = queue.Queue()
    pool = ContainerPool(
        spec=spec, executor_id="ex0", outbox=outbox,
        registry=reg, warm_pool=WarmPool(),
    )
    return pool, reg, outbox


def _env_for(reg, fid, i=0):
    from repro.core import TaskEnvelope, packb

    return TaskEnvelope(task_id=f"t{i}", function_id=fid, payload=packb({"i": i}))


def test_pool_spins_up_on_demand_and_shrinks_idle():
    pool, reg, outbox = _make_pool(_accel_spec(max_workers=3))
    assert pool.live_workers() == 0  # min_workers=0: nothing runs while idle
    fid = reg.register(_echo)
    pool.submit([_env_for(reg, fid, i) for i in range(2)])
    assert 1 <= pool.live_workers() <= 3  # demand-driven spin-up
    for _ in range(2):
        outbox.get(timeout=5)
    # continuously idle past the keep-alive: surplus workers retire
    deadline = time.monotonic() + 5
    while pool.live_workers() > 0 and time.monotonic() < deadline:
        pool.shrink_idle(keep_alive_s=0.01)
        time.sleep(0.02)
    assert pool.live_workers() == 0
    assert pool.shrinks >= 1
    pool.stop()


def test_submit_racing_shrink_still_executes():
    """Regression: a task submitted right after shrink_idle() enqueues its
    stop sentinels must still execute. Doomed-but-alive workers don't count
    as capacity (pending-sentinel accounting), so the racing submit spins up
    a fresh worker instead of stranding the task in a dying pool."""
    pool, reg, outbox = _make_pool(_accel_spec(max_workers=4))
    fid = reg.register(_echo)
    pool.submit([_env_for(reg, fid, i) for i in range(4)])
    for _ in range(4):
        outbox.get(timeout=5)
    # retire everything; workers haven't necessarily consumed the sentinels
    # yet when the next submit arrives — exactly the race window
    assert pool.shrink_idle(keep_alive_s=0.0) > 0
    pool.submit([_env_for(reg, fid, 99)])
    res = outbox.get(timeout=5)  # must not hang
    assert res.error is None
    assert pool.queued() == 0  # sentinels are not backlog
    pool.stop()


def test_pool_respects_max_workers():
    pool, reg, outbox = _make_pool(_accel_spec(max_workers=2))
    fid = reg.register(_echo)
    pool.submit([_env_for(reg, fid, i) for i in range(10)])
    assert pool.live_workers() <= 2
    for _ in range(10):
        outbox.get(timeout=5)
    pool.stop()


def test_pool_keeps_min_workers_alive():
    spec = ContainerSpec(name="c", capabilities={"cpu"}, min_workers=2, max_workers=4)
    pool, reg, outbox = _make_pool(spec)
    assert pool.live_workers() == 2  # persist within the container (§5.3)
    assert pool.shrink_idle(keep_alive_s=0.0) == 0  # never below the floor
    assert pool.live_workers() == 2
    pool.stop()


def test_pool_stop_joins_cleanly():
    """Blocking-get workers retire via stop sentinels: no timeout-poll, and a
    full stop still joins every (idle) worker thread."""
    pool, reg, outbox = _make_pool(
        ContainerSpec(name="c", capabilities={"cpu"}, min_workers=3, max_workers=3)
    )
    fid = reg.register(_echo)
    pool.submit([_env_for(reg, fid, i) for i in range(6)])
    for _ in range(6):
        outbox.get(timeout=5)
    workers = list(pool._workers)
    pool.stop(join=True)
    deadline = time.monotonic() + 2
    while any(w.is_alive() for w in workers) and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not any(w.is_alive() for w in workers)


def test_kill_unblocks_idle_workers():
    """Regression: killed pools must not strand idle workers blocked on the
    inbox forever — kill() wakes each one with a sentinel so the threads
    exit instead of leaking across kill/replace cycles."""
    pool, reg, outbox = _make_pool(
        ContainerSpec(name="c", capabilities={"cpu"}, min_workers=2, max_workers=2)
    )
    workers = list(pool._workers)
    assert all(w.is_alive() for w in workers)
    pool.kill()
    deadline = time.monotonic() + 2
    while any(w.is_alive() for w in workers) and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not any(w.is_alive() for w in workers)


def test_worker_stop_sentinel_drains_queued_work_first():
    pool, reg, outbox = _make_pool(
        ContainerSpec(name="c", capabilities={"cpu"}, min_workers=1, max_workers=1)
    )
    fid = reg.register(_echo)
    pool.submit([_env_for(reg, fid, i) for i in range(3)])
    pool.stop(join=False)  # sentinel queued behind the 3 tasks
    got = [outbox.get(timeout=5) for _ in range(3)]
    assert all(r.error is None for r in got)


# ---------------------------------------------------------------- end to end
def _mixed_fabric():
    svc = FunctionService()
    cpu_ep = svc.make_endpoint("cpu-site", n_executors=1, workers_per_executor=2)
    accel_ep = svc.make_endpoint(
        "accel-site", n_executors=1,
        containers=[default_container_spec(2), _accel_spec()],
    )
    return svc, cpu_ep, accel_ep


def test_capability_routing_pins_to_capable_endpoint():
    svc, cpu_ep, accel_ep = _mixed_fabric()
    try:
        fid = svc.register_function(
            _echo, name="accel_fn",
            requirements=ResourceSpec({"accel"}, preferred_container="accel"),
        )
        futs = [svc.run(fid, {"i": i}) for i in range(8)]
        assert [f.result(10)["i"] for f in futs] == list(range(8))
        # every task was routed to the only capable endpoint
        assert {f.endpoint_id for f in futs} == {accel_ep.endpoint_id}
        assert cpu_ep.completed == 0
        assert accel_ep.completed == 8
    finally:
        svc.shutdown()


def test_endpoint_advertises_pool_union():
    svc, cpu_ep, accel_ep = _mixed_fabric()
    try:
        assert cpu_ep.capabilities() == frozenset({"cpu"})
        assert accel_ep.capabilities() == frozenset({"cpu", "accel"})
    finally:
        svc.shutdown()


def test_unsatisfiable_requirements_fail_fast():
    """Acceptance: a task whose ResourceSpec no live endpoint satisfies fails
    with a CapabilityError immediately — no watchdog timeout."""
    svc, cpu_ep, accel_ep = _mixed_fabric()
    try:
        fid = svc.register_function(_echo, name="gpu_fn", requirements=("gpu",))
        t0 = time.monotonic()
        fut = svc.run(fid, {"i": 1})
        with pytest.raises(CapabilityError, match="gpu"):
            fut.result(timeout=1)
        assert time.monotonic() - t0 < 1.0  # failed fast, not timed out
        snap = svc.metrics.snapshot()
        assert snap["counters"]["container.capability_misses"] >= 1
    finally:
        svc.shutdown()


def test_pinned_endpoint_capability_mismatch_fails_fast():
    svc, cpu_ep, accel_ep = _mixed_fabric()
    try:
        fid = svc.register_function(_echo, name="accel_fn2", requirements=("accel",))
        fut = svc.run(fid, {"i": 1}, endpoint_id=cpu_ep.endpoint_id)
        with pytest.raises(CapabilityError, match="pinned"):
            fut.result(timeout=1)
    finally:
        svc.shutdown()


def test_mixed_batch_partial_capability_failure():
    """One incapable invocation fails alone; its batch siblings still route."""
    svc, cpu_ep, accel_ep = _mixed_fabric()
    try:
        ok = svc.register_function(_echo, name="ok_fn")
        bad = svc.register_function(lambda d: d, name="gpu_fn", requirements=("gpu",))
        futs = svc.run_many([
            Invocation(function_id=ok, payload={"i": 0}),
            Invocation(function_id=bad, payload={"i": 1}),
            Invocation(function_id=ok, payload={"i": 2}),
        ])
        assert futs[0].result(10)["i"] == 0
        assert futs[2].result(10)["i"] == 2
        with pytest.raises(CapabilityError):
            futs[1].result(1)
    finally:
        svc.shutdown()


def test_failover_orphans_with_capability_error_when_no_capable_survivor():
    svc, cpu_ep, accel_ep = _mixed_fabric()
    try:
        fid = svc.register_function(
            lambda d: (time.sleep(d.get("t", 0.0)), d)[1],
            name="slow_accel", requirements=("accel",),
        )
        futs = [svc.run(fid, {"i": i, "t": 2.0}) for i in range(2)]
        time.sleep(0.1)
        accel_ep.kill()  # only capable endpoint dies mid-task
        # fabric watchdog fails the stranded tasks over; the cpu endpoint
        # cannot satisfy {"accel"}, so they orphan with a CapabilityError
        for fut in futs:
            with pytest.raises(CapabilityError):
                fut.result(timeout=10)
    finally:
        svc.shutdown()


def test_map_shards_only_across_capable_endpoints():
    svc, cpu_ep, accel_ep = _mixed_fabric()
    try:
        fid = svc.register_function(_echo, name="accel_map", requirements=("accel",))
        outs = svc.map(fid, [{"i": i} for i in range(10)], timeout=20)
        assert [o["i"] for o in outs] == list(range(10))
        assert cpu_ep.completed == 0 and accel_ep.completed == 10
    finally:
        svc.shutdown()


def test_container_metrics_published():
    svc, cpu_ep, accel_ep = _mixed_fabric()
    try:
        fid = svc.register_function(_echo, name="m_fn", requirements=("accel",))
        [f.result(10) for f in (svc.run(fid, {"i": i}) for i in range(3))]
        for ex in accel_ep.executors.values():
            ex.maintain()
        snap = svc.metrics.snapshot()
        gauges = snap["gauges"]
        sizes = {k: v for k, v in gauges.items() if k.startswith("container.pool_size")}
        assert any("container=accel" in k for k in sizes), sizes
        depths = [k for k in gauges if k.startswith("container.queue_depth")]
        assert depths
    finally:
        svc.shutdown()


def test_seed_container_names_still_work_as_cache_keys():
    """Seed parity: container names with no matching spec and no requirements
    land in the default pool, warm-keyed by the requested variant name."""
    svc = FunctionService()
    ep = svc.make_endpoint("plain", n_executors=1, workers_per_executor=2)
    try:
        fid = svc.register_function(_echo, name="variant_fn")
        assert svc.run(fid, {"i": 1}, container="variant-a", sync=True, timeout=10)["i"] == 1
        assert ep.has_warm((fid, "variant-a"))
    finally:
        svc.shutdown()


# ---------------------------------------------------------------- tracebacks
_canary_refs = {}


class _Canary:
    pass


def _failing(doc):
    canary = _Canary()
    _canary_refs["w"] = weakref.ref(canary)
    raise ValueError("boom with a local alive")


def test_failure_does_not_pin_frames():
    """TaskResult.exception crosses the executor boundary without its
    traceback: the failed call's locals must be collectable immediately."""
    svc = FunctionService()
    svc.make_endpoint("tb", n_executors=1, workers_per_executor=1)
    try:
        fid = svc.register_function(_failing, name="failing")
        fut = svc.run(fid, {}, max_retries=0)
        exc = fut.exception(timeout=10)
        assert isinstance(exc, ValueError)
        assert exc.__traceback__ is None  # stripped at the boundary
        gc.collect()
        assert _canary_refs["w"]() is None  # no frame pins the local
        with pytest.raises(ValueError, match="boom"):
            fut.result(0)
    finally:
        svc.shutdown()
