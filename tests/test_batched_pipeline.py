"""Batched task-flow pipeline: TaskBatch/ResultBatch framing, the
flush-on-size / flush-on-deadline coalescer, batch submission through the
Forwarder, capacity-pulled endpoint dispatch, and whole-batch failover."""
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on clean environments
    from _hypothesis_stub import given, settings, st

from repro.core import (
    BatchCoalescer,
    Forwarder,
    FunctionService,
    ResultBatch,
    TaskBatch,
    TaskEnvelope,
    TaskFuture,
    iter_frames,
)


# ---------------------------------------------------------------- coalescer
def test_coalescer_flush_on_size():
    c = BatchCoalescer(max_batch=3, max_delay_s=60.0)
    assert c.add("a") is None
    assert c.add("b") is None
    assert c.add("c") == ["a", "b", "c"]  # third add fills the frame
    assert len(c) == 0
    assert c.poll() is None


def test_coalescer_flush_on_deadline():
    c = BatchCoalescer(max_batch=100, max_delay_s=0.5)
    c.add("a", now=10.0)
    c.add("b", now=10.1)
    assert c.poll(now=10.4) is None          # oldest is 0.4s old: not yet
    assert c.poll(now=10.6) == ["a", "b"]    # 0.6s old: deadline expired
    assert c.poll(now=99.0) is None          # nothing pending


def test_coalescer_zero_delay_flushes_immediately():
    c = BatchCoalescer(max_batch=100, max_delay_s=0.0)
    c.add(1)
    assert c.poll() == [1]


def test_coalescer_flush_drains_everything():
    c = BatchCoalescer(max_batch=100, max_delay_s=60.0)
    for i in range(5):
        c.add(i)
    assert c.flush() == [0, 1, 2, 3, 4]
    assert c.flush() == []


def test_coalescer_rejects_bad_knobs():
    with pytest.raises(ValueError):
        BatchCoalescer(max_batch=0)
    with pytest.raises(ValueError):
        BatchCoalescer(max_delay_s=-1.0)


@given(
    ops=st.lists(
        st.one_of(st.just("poll"), st.integers(min_value=0, max_value=10)),
        max_size=200,
    ),
    max_batch=st.integers(min_value=1, max_value=7),
    max_delay_s=st.floats(min_value=0.0, max_value=2.0, allow_nan=False),
)
@settings(max_examples=200, deadline=None)
def test_coalescer_never_drops_or_duplicates(ops, max_batch, max_delay_s):
    """Under any interleaving of adds, deadline polls, and an advancing clock,
    every added item comes back exactly once, in insertion order."""
    c = BatchCoalescer(max_batch=max_batch, max_delay_s=max_delay_s)
    clock = 0.0
    added, flushed = [], []
    for seq, op in enumerate(ops):
        if op == "poll":
            clock += max_delay_s / 3 if max_delay_s else 0.25
            out = c.poll(now=clock)
            if out:
                flushed.extend(out)
        else:
            item = (seq, op)
            added.append(item)
            out = c.add(item, now=clock)
            if out:
                flushed.extend(out)
    flushed.extend(c.flush())
    assert flushed == added  # exactly once each, order preserved


# ---------------------------------------------------------------- framing
def _env(i, fn="f"):
    return TaskEnvelope(task_id=f"t{i}", function_id=fn, payload=b"")


def test_iter_frames_slices_to_max_batch():
    pairs = [(_env(i), TaskFuture(f"t{i}")) for i in range(10)]
    frames = list(iter_frames(pairs, max_batch=4))
    assert [len(f) for f in frames] == [4, 4, 2]
    seen = [env.task_id for f in frames for env in f]
    assert seen == [f"t{i}" for i in range(10)]
    # each envelope is stamped with its frame's identity
    for frame in frames:
        assert all(env.batch_id == frame.batch_id for env in frame)


def test_task_batch_stamps_batch_id():
    envs = [_env(i) for i in range(3)]
    batch = TaskBatch(envelopes=envs, futures=[TaskFuture(e.task_id) for e in envs])
    assert len(batch) == 3
    assert all(e.batch_id == batch.batch_id for e in batch)


# ------------------------------------------------- forwarder batch submission
class BatchFakeEndpoint:
    """Endpoint-shaped fake that records delivered TaskBatch frames."""

    def __init__(self, eid, capacity=4, alive=True):
        self.endpoint_id = eid
        self._capacity = capacity
        self._alive = alive
        self.batches = []

    def is_alive(self, max_heartbeat_age_s=None):
        return self._alive

    def capacity(self):
        return self._capacity

    def has_warm(self, key):
        return False

    def submit_batch(self, batch):
        self.batches.append(batch)

    def submit(self, env, future):  # pragma: no cover - batch surface preferred
        raise AssertionError("batched forwarder must use submit_batch")


@pytest.fixture()
def fwd_factory():
    created = []

    def make(endpoints, **kwargs):
        kwargs.setdefault("policy", "least_outstanding")
        f = Forwarder(seed=0, **kwargs)
        for ep in endpoints:
            f.register(ep)
        created.append(f)
        return f

    yield make
    for f in created:
        f.shutdown()


def _pairs(n, start=0):
    return [(_env(i + start), TaskFuture(f"t{i + start}")) for i in range(n)]


def test_submit_many_delivers_one_frame_per_endpoint(fwd_factory):
    ep = BatchFakeEndpoint("a")
    fwd = fwd_factory([ep], max_batch=64)
    chosen = fwd.submit_many(_pairs(10))
    assert chosen == ["a"] * 10
    assert len(ep.batches) == 1 and len(ep.batches[0]) == 10


def test_submit_many_respects_max_batch_framing(fwd_factory):
    ep = BatchFakeEndpoint("a")
    fwd = fwd_factory([ep], max_batch=4)
    fwd.submit_many(_pairs(10), endpoint_id="a")
    assert [len(b) for b in ep.batches] == [4, 4, 2]
    stats = fwd.stats()
    assert stats["batches_delivered"] == 3 and stats["tasks_delivered"] == 10


def test_submit_many_pinned_and_unknown_endpoint(fwd_factory):
    a, b = BatchFakeEndpoint("a"), BatchFakeEndpoint("b")
    fwd = fwd_factory([a, b])
    assert fwd.submit_many(_pairs(3), endpoint_id="b") == ["b"] * 3
    assert not a.batches and len(b.batches) == 1
    with pytest.raises(KeyError):
        fwd.submit_many(_pairs(1, start=90), endpoint_id="nope")


def test_submit_many_spreads_by_policy(fwd_factory):
    a, b = BatchFakeEndpoint("a"), BatchFakeEndpoint("b")
    fwd = fwd_factory([a, b])
    chosen = fwd.submit_many(_pairs(8))  # futures never complete
    assert sorted(chosen) == ["a"] * 4 + ["b"] * 4  # least_outstanding spreads
    assert sum(len(x) for x in a.batches) == 4
    assert sum(len(x) for x in b.batches) == 4


def test_deferred_pump_coalesces_on_deadline(fwd_factory):
    ep = BatchFakeEndpoint("a")
    fwd = fwd_factory([ep], max_batch=1000, max_delay_s=0.04)
    for env, fut in _pairs(5):
        fwd.submit(env, fut)
    assert not ep.batches  # inside the coalescing window: nothing delivered yet
    deadline = time.monotonic() + 2
    while not ep.batches and time.monotonic() < deadline:
        time.sleep(0.005)
    assert len(ep.batches) == 1 and len(ep.batches[0]) == 5  # one frame for all


def test_deferred_flush_on_size_is_inline(fwd_factory):
    ep = BatchFakeEndpoint("a")
    fwd = fwd_factory([ep], max_batch=3, max_delay_s=30.0)
    fwd.submit_many(_pairs(3))
    assert len(ep.batches) == 1 and len(ep.batches[0]) == 3  # no pump wait
    fwd.submit_many(_pairs(2, start=10))
    assert fwd.stats()["endpoints"]["a"]["pending"] == 2  # below size: queued
    assert fwd.pump_once(force=True) == 2


# ------------------------------------------------- end-to-end batched path
def _ident(doc):
    return doc


def _sleepy(doc):
    time.sleep(doc.get("t", 0.03))
    return {"i": doc.get("i", -1)}


def test_batched_path_matches_per_task_results_and_order():
    svc = FunctionService()
    svc.make_endpoint("cmp", n_executors=2, workers_per_executor=2, prefetch=4)
    fid = svc.register_function(_ident)
    payloads = [{"i": i} for i in range(40)]

    per_task = [svc.run(fid, p) for p in payloads]
    batched = svc.batch_run(fid, payloads)
    assert [f.result(30)["i"] for f in per_task] == list(range(40))
    assert [f.result(30)["i"] for f in batched] == list(range(40))
    assert svc.forwarder.stats()["mean_batch_size"] > 1.0
    svc.shutdown()


def test_batched_sync_returns_results():
    svc = FunctionService()
    svc.make_endpoint("sy", n_executors=1, workers_per_executor=2)
    fid = svc.register_function(_ident)
    outs = svc.batch_run(fid, [{"i": i} for i in range(5)], sync=True, timeout=30)
    assert [o["i"] for o in outs] == list(range(5))
    svc.shutdown()


def test_batched_memoization_served_without_submission():
    svc = FunctionService()
    svc.make_endpoint("bm", n_executors=1, workers_per_executor=1)
    calls = {"n": 0}

    def counted(doc):
        calls["n"] += 1
        return {"v": doc["x"]}

    fid = svc.register_function(counted)
    svc.run(fid, {"x": 1}, memoize=True).result(20)
    futs = svc.batch_run(fid, [{"x": 1}] * 6, memoize=True)
    assert [f.result(20)["v"] for f in futs] == [1] * 6
    assert calls["n"] == 1  # every repeat served from the memo cache
    svc.shutdown()


def test_in_flight_batch_fails_over_intact():
    """Kill an endpoint holding a whole pinned batch: every task of the frame
    is re-delivered (as batches) to the survivor and completes."""
    svc = FunctionService(policy="least_outstanding")
    svc.forwarder.liveness_threshold_s = 0.2
    svc.forwarder.watchdog_interval_s = 0.02
    ep_a = svc.make_endpoint("bfa", n_executors=1, workers_per_executor=2)
    svc.make_endpoint("bfb", n_executors=1, workers_per_executor=2)
    fid = svc.register_function(_sleepy)
    futs = svc.batch_run(
        fid, [{"i": i, "t": 0.08} for i in range(12)], endpoint_id=ep_a.endpoint_id
    )
    time.sleep(0.05)
    ep_a.kill()
    results = [f.result(30) for f in futs]
    assert sorted(r["i"] for r in results) == list(range(12))
    assert svc.forwarder.failovers > 0
    # failover re-delivery also travelled in frames, not task-by-task
    stats = svc.forwarder.stats()
    assert stats["batches_delivered"] < stats["tasks_delivered"]
    svc.shutdown()


def test_batch_queued_in_pump_fails_over_on_death():
    """Tasks routed to a dead endpoint but still waiting in its submit queue
    must not be delivered to the corpse — they fail over with the rest."""
    svc = FunctionService(
        policy="least_outstanding",
        forwarder=Forwarder(max_batch=1000, max_delay_s=0.5, seed=0),
    )
    svc.forwarder.liveness_threshold_s = 0.15
    svc.forwarder.watchdog_interval_s = 0.02
    ep_a = svc.make_endpoint("pqa", n_executors=1, workers_per_executor=2)
    svc.make_endpoint("pqb", n_executors=1, workers_per_executor=2)
    fid = svc.register_function(_sleepy)
    futs = svc.batch_run(
        fid, [{"i": i, "t": 0.0} for i in range(6)], endpoint_id=ep_a.endpoint_id
    )
    ep_a.kill()  # dies while the batch sits in the per-endpoint submit queue
    results = [f.result(30) for f in futs]
    assert sorted(r["i"] for r in results) == list(range(6))
    svc.shutdown()


def test_speculation_bookkeeping_pruned_after_completion():
    svc = FunctionService()
    ep = svc.make_endpoint("spp", n_executors=2, workers_per_executor=1,
                           heartbeat_interval_s=0.05, speculation=True,
                           speculation_multiplier=2.0)
    fid = svc.register_function(_sleepy)
    [svc.run(fid, {"i": i, "t": 0.01}).result(10) for i in range(10)]
    fut = svc.run(fid, {"i": 99, "t": 0.5})  # straggler: 50x baseline
    assert fut.result(20)["i"] == 99
    deadline = time.monotonic() + 5
    while ep._speculated and time.monotonic() < deadline:
        time.sleep(0.02)
    assert not ep._speculated  # entries pruned once either copy delivers
    svc.shutdown()


def test_executor_outbox_drains_as_result_batches():
    svc = FunctionService()
    ep = svc.make_endpoint("rb", n_executors=1, workers_per_executor=4, prefetch=8)
    frames = []
    real_put = ep.result_queue.put
    ep.result_queue.put = lambda item: (frames.append(item), real_put(item))[1]
    fid = svc.register_function(_ident)
    futs = svc.batch_run(fid, [{"i": i} for i in range(32)])
    [f.result(30) for f in futs]
    assert frames and all(isinstance(f, ResultBatch) for f in frames)
    assert sum(len(f) for f in frames) >= 32
    svc.shutdown()
