"""Unit + property tests for core FaaS components."""
import time

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property-based cases skip without the dev extra
    from _hypothesis_stub import given, settings, st

from repro.core import (
    FunctionRegistry,
    HeartbeatMonitor,
    MemoCache,
    TaskEnvelope,
    WarmPool,
    hash_function,
    packb,
    payload_hash,
    stack_payloads,
    unpackb,
    unstack_results,
)
from repro.core.batching import group_by_function
from repro.core.heartbeat import LatencyTracker


# ---------------------------------------------------------------- registry
def test_hash_function_stable_and_content_sensitive():
    def f(x):
        return x + 1

    def g(x):
        return x + 2

    assert hash_function(f) == hash_function(f)
    assert hash_function(f) != hash_function(g)
    assert hash_function(f, static="a") != hash_function(f, static="b")


def test_hash_function_closure_sensitivity():
    def make(k):
        def h(x):
            return x + k

        return h

    assert hash_function(make(1)) != hash_function(make(2))


def test_registry_idempotent_and_lookup():
    reg = FunctionRegistry()
    f = lambda d: d  # noqa: E731
    fid1 = reg.register(f, name="id")
    fid2 = reg.register(f, name="id")
    assert fid1 == fid2
    assert reg.get(fid1).name == "id"
    with pytest.raises(KeyError):
        reg.get("nope")


def test_authorized_requires_identity_match():
    """Regression: anonymous-owned functions used to be world-executable —
    ``authorized()`` treated owner="anonymous" as a wildcard. Ownership is a
    strict identity comparison now; ``public=True`` is the only open door."""
    reg = FunctionRegistry()
    private = reg.register(lambda d: d, name="private")          # owner=anonymous
    owned = reg.register(lambda d: d + 0, name="owned", owner="alice")
    shared = reg.register(lambda d: d + 1, name="shared", owner="alice", public=True)

    # the anonymous-owner default only opens the no-authority deployment
    assert reg.authorized(private, "anonymous")
    assert not reg.authorized(private, "mallory")
    # owners invoke their own functions; everyone else is rejected
    assert reg.authorized(owned, "alice")
    assert not reg.authorized(owned, "bob")
    assert not reg.authorized(owned, "anonymous")
    # public stays the explicit opt-in for cross-user execution
    assert reg.authorized(shared, "bob")


def test_registry_requirements_normalized():
    from repro.core import ResourceSpec

    reg = FunctionRegistry()
    fid = reg.register(lambda d: d, name="caps", requirements=("tpu", "cpu"))
    spec = reg.get(fid).requirements
    assert isinstance(spec, ResourceSpec)
    assert spec.capabilities == frozenset({"tpu", "cpu"})
    fid2 = reg.register(
        lambda d: d * 1, name="pref",
        requirements=ResourceSpec(frozenset({"jit"}), preferred_container="jit"),
    )
    assert reg.get(fid2).requirements.preferred_container == "jit"
    assert reg.get(fid2).requirements.satisfied_by({"cpu", "jit"})
    assert not reg.get(fid2).requirements.satisfied_by({"cpu"})


# ---------------------------------------------------------------- serializer
payload_leaf = st.one_of(
    st.integers(min_value=-(2**40), max_value=2**40),
    st.floats(allow_nan=False, allow_infinity=False, width=32),
    st.text(max_size=16),
    st.booleans(),
    st.none(),
    st.binary(max_size=32),
)
payload_tree = st.recursive(
    payload_leaf,
    lambda children: st.one_of(
        st.lists(children, max_size=4),
        st.dictionaries(st.text(max_size=6), children, max_size=4),
        st.tuples(children, children),
    ),
    max_leaves=12,
)


@given(payload_tree)
@settings(max_examples=80, deadline=None)
def test_serializer_roundtrip_property(tree):
    out = unpackb(packb(tree))

    def norm(x):
        if isinstance(x, tuple):
            return [norm(v) for v in x]
        if isinstance(x, list):
            return [norm(v) for v in x]
        if isinstance(x, dict):
            return {k: norm(v) for k, v in x.items()}
        return x

    assert norm(out) == norm(tree)


@given(payload_tree)
@settings(max_examples=50, deadline=None)
def test_payload_hash_deterministic(tree):
    assert payload_hash(tree) == payload_hash(tree)


def test_serializer_ndarray_roundtrip():
    for dt in (np.float32, np.int64, np.bool_, np.float16, np.uint8):
        arr = (np.arange(24).reshape(2, 3, 4) % 2).astype(dt)
        out = unpackb(packb({"a": arr}))["a"]
        np.testing.assert_array_equal(out, arr)
        assert out.dtype == arr.dtype


def test_payload_hash_dict_order_invariant():
    a = {"x": 1, "y": np.ones(3)}
    b = {"y": np.ones(3), "x": 1}
    assert payload_hash(a) == payload_hash(b)


# ---------------------------------------------------------------- memoization
def test_memo_lru_eviction_and_stats():
    memo = MemoCache(max_entries=2)
    memo.put("f", "a", 1)
    memo.put("f", "b", 2)
    memo.put("f", "c", 3)  # evicts ("f","a")
    hit, _ = memo.get("f", "a")
    assert not hit
    hit, v = memo.get("f", "c")
    assert hit and v == 3
    s = memo.stats()
    assert s["entries"] == 2 and s["hits"] == 1 and s["misses"] == 1


def test_memo_invalidate():
    memo = MemoCache()
    memo.put("f", "a", 1)
    memo.put("g", "a", 2)
    assert memo.invalidate("f") == 1
    assert len(memo) == 1


# ---------------------------------------------------------------- warming
def test_warm_pool_hit_miss_ttl():
    pool = WarmPool(ttl_s=0.05, max_entries=4)
    calls = []

    def compile_fn():
        calls.append(1)
        return lambda d: d

    _, cold, _ = pool.get_or_compile(("f", "c"), compile_fn)
    assert cold and len(calls) == 1
    _, cold, _ = pool.get_or_compile(("f", "c"), compile_fn)
    assert not cold and len(calls) == 1  # warm hit
    time.sleep(0.08)
    _, cold, _ = pool.get_or_compile(("f", "c"), compile_fn)
    assert cold and len(calls) == 2  # TTL expired -> cold again
    assert pool.stats()["cold_starts"] == 2


def test_warm_pool_lru_bound():
    pool = WarmPool(ttl_s=100, max_entries=2)
    for i in range(4):
        pool.get_or_compile(("f", i), lambda: i)
    assert len(pool) == 2
    assert pool.stats()["evictions"] == 2


# scheduler policy/filter coverage lives in tests/test_scheduler.py


# ---------------------------------------------------------------- batching
@given(st.lists(st.integers(0, 100), min_size=1, max_size=16))
@settings(max_examples=40, deadline=None)
def test_stack_unstack_no_loss_no_dup(values):
    payloads = [{"x": np.full(3, v, np.int64), "tag": "same"} for v in values]
    stacked = stack_payloads(payloads)
    assert stacked["x"].shape == (len(values), 3)
    outs = unstack_results(stacked, len(values))
    got = [int(o["x"][0]) for o in outs]
    assert got == values  # order preserved, nothing lost or duplicated


def test_stack_rejects_mismatched_structure():
    with pytest.raises(ValueError):
        stack_payloads([{"a": np.ones(2)}, {"b": np.ones(2)}])
    with pytest.raises(ValueError):
        stack_payloads([{"a": np.ones(2), "t": 1}, {"a": np.ones(2), "t": 2}])


def test_group_by_function():
    envs = [
        TaskEnvelope(task_id=str(i), function_id="f" if i % 2 else "g", payload=b"")
        for i in range(6)
    ]
    groups = group_by_function(envs)
    assert len(groups) == 2
    assert sum(len(v) for v in groups.values()) == 6


# ---------------------------------------------------------------- heartbeat
def test_heartbeat_dead_detection():
    mon = HeartbeatMonitor(interval_s=0.01, threshold=2.0)
    mon.register("a")
    mon.register("b")
    for _ in range(3):
        mon.beat("b")
        time.sleep(0.01)
    dead = mon.dead()
    assert "a" in dead and "b" not in dead
    mon.suspend("a")
    assert "a" not in mon.dead()  # suspended are not re-reported


def test_latency_tracker_p95():
    t = LatencyTracker()
    assert t.p95() is None
    for v in range(100):
        t.record(v / 100)
    assert 0.9 <= t.p95() <= 0.99
