"""Unit + property tests for core FaaS components."""
import time

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property-based cases skip without the dev extra
    from _hypothesis_stub import given, settings, st

from repro.core import (
    FunctionRegistry,
    HeartbeatMonitor,
    MemoCache,
    Scheduler,
    TaskEnvelope,
    WarmPool,
    hash_function,
    packb,
    payload_hash,
    stack_payloads,
    unpackb,
    unstack_results,
)
from repro.core.batching import group_by_function
from repro.core.heartbeat import LatencyTracker


# ---------------------------------------------------------------- registry
def test_hash_function_stable_and_content_sensitive():
    def f(x):
        return x + 1

    def g(x):
        return x + 2

    assert hash_function(f) == hash_function(f)
    assert hash_function(f) != hash_function(g)
    assert hash_function(f, static="a") != hash_function(f, static="b")


def test_hash_function_closure_sensitivity():
    def make(k):
        def h(x):
            return x + k

        return h

    assert hash_function(make(1)) != hash_function(make(2))


def test_registry_idempotent_and_lookup():
    reg = FunctionRegistry()
    f = lambda d: d  # noqa: E731
    fid1 = reg.register(f, name="id")
    fid2 = reg.register(f, name="id")
    assert fid1 == fid2
    assert reg.get(fid1).name == "id"
    with pytest.raises(KeyError):
        reg.get("nope")


# ---------------------------------------------------------------- serializer
payload_leaf = st.one_of(
    st.integers(min_value=-(2**40), max_value=2**40),
    st.floats(allow_nan=False, allow_infinity=False, width=32),
    st.text(max_size=16),
    st.booleans(),
    st.none(),
    st.binary(max_size=32),
)
payload_tree = st.recursive(
    payload_leaf,
    lambda children: st.one_of(
        st.lists(children, max_size=4),
        st.dictionaries(st.text(max_size=6), children, max_size=4),
        st.tuples(children, children),
    ),
    max_leaves=12,
)


@given(payload_tree)
@settings(max_examples=80, deadline=None)
def test_serializer_roundtrip_property(tree):
    out = unpackb(packb(tree))

    def norm(x):
        if isinstance(x, tuple):
            return [norm(v) for v in x]
        if isinstance(x, list):
            return [norm(v) for v in x]
        if isinstance(x, dict):
            return {k: norm(v) for k, v in x.items()}
        return x

    assert norm(out) == norm(tree)


@given(payload_tree)
@settings(max_examples=50, deadline=None)
def test_payload_hash_deterministic(tree):
    assert payload_hash(tree) == payload_hash(tree)


def test_serializer_ndarray_roundtrip():
    for dt in (np.float32, np.int64, np.bool_, np.float16, np.uint8):
        arr = (np.arange(24).reshape(2, 3, 4) % 2).astype(dt)
        out = unpackb(packb({"a": arr}))["a"]
        np.testing.assert_array_equal(out, arr)
        assert out.dtype == arr.dtype


def test_payload_hash_dict_order_invariant():
    a = {"x": 1, "y": np.ones(3)}
    b = {"y": np.ones(3), "x": 1}
    assert payload_hash(a) == payload_hash(b)


# ---------------------------------------------------------------- memoization
def test_memo_lru_eviction_and_stats():
    memo = MemoCache(max_entries=2)
    memo.put("f", "a", 1)
    memo.put("f", "b", 2)
    memo.put("f", "c", 3)  # evicts ("f","a")
    hit, _ = memo.get("f", "a")
    assert not hit
    hit, v = memo.get("f", "c")
    assert hit and v == 3
    s = memo.stats()
    assert s["entries"] == 2 and s["hits"] == 1 and s["misses"] == 1


def test_memo_invalidate():
    memo = MemoCache()
    memo.put("f", "a", 1)
    memo.put("g", "a", 2)
    assert memo.invalidate("f") == 1
    assert len(memo) == 1


# ---------------------------------------------------------------- warming
def test_warm_pool_hit_miss_ttl():
    pool = WarmPool(ttl_s=0.05, max_entries=4)
    calls = []

    def compile_fn():
        calls.append(1)
        return lambda d: d

    _, cold, _ = pool.get_or_compile(("f", "c"), compile_fn)
    assert cold and len(calls) == 1
    _, cold, _ = pool.get_or_compile(("f", "c"), compile_fn)
    assert not cold and len(calls) == 1  # warm hit
    time.sleep(0.08)
    _, cold, _ = pool.get_or_compile(("f", "c"), compile_fn)
    assert cold and len(calls) == 2  # TTL expired -> cold again
    assert pool.stats()["cold_starts"] == 2


def test_warm_pool_lru_bound():
    pool = WarmPool(ttl_s=100, max_entries=2)
    for i in range(4):
        pool.get_or_compile(("f", i), lambda: i)
    assert len(pool) == 2
    assert pool.stats()["evictions"] == 2


# ---------------------------------------------------------------- scheduler
class FakeExecutor:
    def __init__(self, eid, cap, warm=()):
        self.executor_id = eid
        self._cap = cap
        self._warm = set(warm)

    def accepting(self):
        return True

    def free_capacity(self):
        return self._cap

    def has_warm(self, key):
        return key in self._warm


def _env():
    return TaskEnvelope(task_id="t", function_id="f", payload=b"")


def test_scheduler_least_loaded():
    s = Scheduler("least_loaded")
    exs = [FakeExecutor("a", 1), FakeExecutor("b", 5)]
    assert s.choose(exs, _env()).executor_id == "b"


def test_scheduler_warm_affinity():
    s = Scheduler("warm_affinity")
    exs = [FakeExecutor("a", 9), FakeExecutor("b", 1, warm=[("f", "default")])]
    assert s.choose(exs, _env()).executor_id == "b"


def test_scheduler_round_robin_cycles():
    s = Scheduler("round_robin")
    exs = [FakeExecutor("a", 1), FakeExecutor("b", 1)]
    picks = [s.choose(exs, _env()).executor_id for _ in range(4)]
    assert picks == ["a", "b", "a", "b"]


def test_scheduler_none_when_no_capacity():
    s = Scheduler("random")
    assert s.choose([FakeExecutor("a", 0)], _env()) is None


# ---------------------------------------------------------------- batching
@given(st.lists(st.integers(0, 100), min_size=1, max_size=16))
@settings(max_examples=40, deadline=None)
def test_stack_unstack_no_loss_no_dup(values):
    payloads = [{"x": np.full(3, v, np.int64), "tag": "same"} for v in values]
    stacked = stack_payloads(payloads)
    assert stacked["x"].shape == (len(values), 3)
    outs = unstack_results(stacked, len(values))
    got = [int(o["x"][0]) for o in outs]
    assert got == values  # order preserved, nothing lost or duplicated


def test_stack_rejects_mismatched_structure():
    with pytest.raises(ValueError):
        stack_payloads([{"a": np.ones(2)}, {"b": np.ones(2)}])
    with pytest.raises(ValueError):
        stack_payloads([{"a": np.ones(2), "t": 1}, {"a": np.ones(2), "t": 2}])


def test_group_by_function():
    envs = [
        TaskEnvelope(task_id=str(i), function_id="f" if i % 2 else "g", payload=b"")
        for i in range(6)
    ]
    groups = group_by_function(envs)
    assert len(groups) == 2
    assert sum(len(v) for v in groups.values()) == 6


# ---------------------------------------------------------------- heartbeat
def test_heartbeat_dead_detection():
    mon = HeartbeatMonitor(interval_s=0.01, threshold=2.0)
    mon.register("a")
    mon.register("b")
    for _ in range(3):
        mon.beat("b")
        time.sleep(0.01)
    dead = mon.dead()
    assert "a" in dead and "b" not in dead
    mon.suspend("a")
    assert "a" not in mon.dead()  # suspended are not re-reported


def test_latency_tracker_p95():
    t = LatencyTracker()
    assert t.p95() is None
    for v in range(100):
        t.record(v / 100)
    assert 0.9 <= t.p95() <= 0.99
