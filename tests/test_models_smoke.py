"""Per-arch smoke tests: reduced config of the same family, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models.model import Model
from repro.training import optimizer as opt
from repro.training.steps import build_train_step

B, S = 2, 32


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss_finite(arch, key):
    cfg = get_reduced(arch)
    model = Model(cfg)
    params = model.init(key)
    batch = make_batch(cfg, B, S)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    assert metrics["ce"] > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_updates_params_no_nan(arch, key):
    cfg = get_reduced(arch)
    model = Model(cfg)
    params = model.init(key)
    state = opt.init_state(params)
    built = build_train_step(model, opt.OptimizerConfig(lr=1e-3, warmup_steps=1,
                                                        total_steps=10))
    step = jax.jit(built.fn)
    batch = make_batch(cfg, B, S)
    new_params, new_state, metrics = step(params, state, batch)
    assert int(new_state["step"]) == 1
    assert bool(jnp.isfinite(metrics["loss"]))
    # at least one leaf changed, none became NaN
    changed = False
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert not bool(jnp.any(jnp.isnan(b.astype(jnp.float32)))), arch
        changed = changed or not np.array_equal(np.asarray(a), np.asarray(b))
    assert changed, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch, key):
    cfg = get_reduced(arch)
    model = Model(cfg)
    params = model.init(key)
    batch = make_batch(cfg, B, S)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    cache0, specs = model.init_cache(B, S + 4)
    tok = jnp.asarray(batch["tokens"][:, :1])
    out, new_cache = jax.jit(model.decode_step)(params, tok, cache0, jnp.int32(0))
    assert out.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache0)


def test_full_configs_match_published_param_counts():
    expected_b = {
        "deepseek-67b": (66, 69),
        "qwen3-moe-235b-a22b": (230, 240),
        "qwen2-moe-a2.7b": (13, 15),
        "minicpm3-4b": (3.8, 4.7),
        "mamba2-2.7b": (2.6, 3.0),
        "zamba2-2.7b": (2.1, 3.0),
        "internvl2-26b": (18, 21),      # LM backbone only (ViT is stubbed)
        "qwen2-0.5b": (0.4, 0.55),
        "qwen1.5-0.5b": (0.4, 0.55),
        "whisper-small": (0.2, 0.4),
    }
    for arch, (lo, hi) in expected_b.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_moe_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    active = cfg.param_count(active_only=True) / 1e9
    assert 20 <= active <= 24  # "A22B"
