"""Fault tolerance (paper §5.3, §6.3): heartbeats, watchdog, re-execution,
elastic replacement, speculation, and the optimizations' behaviours."""
import time

import numpy as np
import pytest

from repro.core import FunctionService, TaskState


def _sleepy(doc):
    time.sleep(doc.get("t", 0.03))
    return {"i": doc.get("i", -1)}


def test_executor_failure_recovers_all_tasks():
    svc = FunctionService()
    ep = svc.make_endpoint("ft", n_executors=2, workers_per_executor=2,
                           heartbeat_interval_s=0.05)
    fid = svc.register_function(_sleepy)
    futs = [svc.run(fid, {"i": i, "t": 0.05}) for i in range(12)]
    time.sleep(0.08)
    ep.kill_executor(0)
    results = [f.result(timeout=30) for f in futs]
    assert sorted(r["i"] for r in results) == list(range(12))
    assert ep.lost_executors == 1
    assert ep.requeued > 0
    svc.shutdown()


def test_elastic_replacement_restores_capacity():
    svc = FunctionService()
    ep = svc.make_endpoint("el", n_executors=2, workers_per_executor=1,
                           heartbeat_interval_s=0.05, elastic=True, max_executors=4)
    fid = svc.register_function(_sleepy)
    before = len(ep.executors)
    futs = [svc.run(fid, {"i": i, "t": 0.03}) for i in range(6)]
    time.sleep(0.05)
    ep.kill_executor(0)
    [f.result(20) for f in futs]
    deadline = time.monotonic() + 5
    while len(ep.executors) < before and time.monotonic() < deadline:
        time.sleep(0.02)
    assert len(ep.executors) >= before  # watchdog replaced the dead block
    svc.shutdown()


def test_task_retries_exhausted_raises():
    svc = FunctionService()
    svc.make_endpoint("rx", n_executors=1, workers_per_executor=1,
                      heartbeat_interval_s=0.05)

    def flaky(doc):
        raise RuntimeError("always fails")

    fid = svc.register_function(flaky)
    fut = svc.run(fid, {}, max_retries=1)
    with pytest.raises(RuntimeError):
        fut.result(20)
    svc.shutdown()


def test_retry_succeeds_after_transient_failure():
    svc = FunctionService()
    svc.make_endpoint("tr", n_executors=1, workers_per_executor=1)
    state = {"n": 0}

    def transient(doc):
        state["n"] += 1
        if state["n"] < 3:
            raise IOError("transient")
        return {"ok": True, "attempts": state["n"]}

    fid = svc.register_function(transient)
    out = svc.run(fid, {}, max_retries=3, sync=True, timeout=20)
    assert out["ok"] and out["attempts"] == 3
    svc.shutdown()


def test_speculative_duplicate_first_result_wins():
    svc = FunctionService()
    svc.make_endpoint("sp", n_executors=2, workers_per_executor=1,
                           heartbeat_interval_s=0.05, speculation=True,
                           speculation_multiplier=2.0)
    fid = svc.register_function(_sleepy)
    # establish a latency baseline
    [svc.run(fid, {"i": i, "t": 0.01}).result(10) for i in range(10)]
    # one straggler: 50x the baseline
    fut = svc.run(fid, {"i": 99, "t": 0.5})
    out = fut.result(20)
    assert out["i"] == 99
    assert fut.state == TaskState.SUCCESS
    svc.shutdown()


def test_memoization_serves_repeats_without_execution():
    svc = FunctionService()
    svc.make_endpoint("memo", n_executors=1, workers_per_executor=1)
    calls = {"n": 0}

    def counted(doc):
        calls["n"] += 1
        return {"v": int(np.asarray(doc["x"]).sum())}

    fid = svc.register_function(counted)
    p = {"x": np.arange(5)}
    a = svc.run(fid, p, memoize=True).result(10)
    b_fut = svc.run(fid, p, memoize=True)
    b = b_fut.result(10)
    assert a == b
    assert calls["n"] == 1
    assert b_fut.state == TaskState.MEMOIZED
    # different payload executes again
    svc.run(fid, {"x": np.arange(6)}, memoize=True).result(10)
    assert calls["n"] == 2
    svc.shutdown()


def test_user_batched_run_returns_per_request_futures():
    svc = FunctionService()
    svc.make_endpoint("ub", n_executors=1, workers_per_executor=1)

    def double(doc):
        return {"y": np.asarray(doc["x"]) * 2}

    fid = svc.register_function(double)
    futs = svc.batch_run(fid, [{"x": np.full(2, i)} for i in range(5)],
                         user_batched=True)
    outs = [f.result(10) for f in futs]
    assert [int(o["y"][0]) for o in outs] == [0, 2, 4, 6, 8]
    svc.shutdown()


def test_prefetch_capacity_advertised():
    svc = FunctionService()
    ep = svc.make_endpoint("pf", n_executors=1, workers_per_executor=2, prefetch=4)
    ex = list(ep.executors.values())[0]
    # per-container advertisement: idle workers + prefetch allowance
    assert ex.free_capacity("default") == 2 + 4
    svc.shutdown()
