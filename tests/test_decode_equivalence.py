"""The serving-correctness keystone: incremental decode must reproduce the
full-sequence forward logits (per family: GQA cache, MLA compressed cache,
Mamba2 conv+SSM state, hybrid shared-attn cache, whisper self+cross cache)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import ARCH_IDS, get_reduced
from repro.models.model import Model

B, S = 2, 24
PREFILL = 16  # prefill length; decode the rest token by token


def full_logits(model, params, batch):
    h, _ = model.forward(params, batch)
    return model._logits(params, h)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch, key):
    cfg = get_reduced(arch).with_(dtype="float32")
    if cfg.moe is not None:
        # avoid capacity drops so dispatch is exact (prefill T >> decode T)
        cfg = cfg.with_(moe=cfg.moe.__class__(**{
            **cfg.moe.__dict__, "capacity_factor": 8.0,
        }))
    model = Model(cfg)
    params = model.init(key)
    batch = make_batch(cfg, B, S)
    ref = np.asarray(full_logits(model, params, batch))

    if cfg.family == "vlm":
        pre_tokens = batch["tokens"][:, :PREFILL - cfg.n_patches]
        pre_batch = dict(batch, tokens=pre_tokens)
        decode_tokens = batch["tokens"][:, PREFILL - cfg.n_patches:]
    else:
        pre_batch = dict(batch, tokens=batch["tokens"][:, :PREFILL])
        decode_tokens = batch["tokens"][:, PREFILL:]

    logits_p, cache = jax.jit(model.prefill)(params, pre_batch)
    np.testing.assert_allclose(
        np.asarray(logits_p), ref[:, PREFILL - 1], rtol=2e-4, atol=2e-4,
        err_msg=f"{arch}: prefill last-logits mismatch",
    )

    # pad the prefill cache out to S slots so decode has room
    if cfg.family in ("ssm",):
        full_cache = cache  # state caches are position-free
    else:
        cache0, _ = model.init_cache(B, S)
        full_cache = jax.tree.map(_blit, cache0, cache)

    decode = jax.jit(model.decode_step)
    cur = full_cache
    n_steps = decode_tokens.shape[1] - 1
    for i in range(n_steps):
        tok = jnp.asarray(decode_tokens[:, i:i + 1])
        pos = jnp.int32(PREFILL + i)
        logits_d, cur = decode(params, tok, cur, pos)
        np.testing.assert_allclose(
            np.asarray(logits_d), ref[:, PREFILL + i], rtol=3e-4, atol=3e-4,
            err_msg=f"{arch}: decode step {i} mismatch",
        )


def _blit(zeros_leaf, cache_leaf):
    """Copy a prefill cache (seq len PREFILL) into a zeroed S-slot cache.
    Sequence-length axes differ; all other axes match."""
    if zeros_leaf.shape == cache_leaf.shape:
        return cache_leaf.astype(zeros_leaf.dtype)
    pads = []
    for a, b in zip(zeros_leaf.shape, cache_leaf.shape):
        assert a >= b, (zeros_leaf.shape, cache_leaf.shape)
        pads.append((0, a - b))
    return jnp.pad(cache_leaf.astype(zeros_leaf.dtype), pads)


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-2.7b"])
def test_ssm_state_is_constant_size(arch):
    """long_500k applicability: decode state size must not grow with the
    context length (the reason these archs run the 500k cell)."""
    cfg = get_reduced(arch)
    model = Model(cfg)
    if cfg.family == "ssm":
        c1, _ = model.init_cache(1, 128)
        c2, _ = model.init_cache(1, 4096)
        assert jax.tree.map(lambda x: x.shape, c1) == jax.tree.map(lambda x: x.shape, c2)
    else:
        c1, _ = model.init_cache(1, 128)
        mamba_1 = jax.tree.map(lambda x: x.shape, c1["mamba"])
        c2, _ = model.init_cache(1, 4096)
        mamba_2 = jax.tree.map(lambda x: x.shape, c2["mamba"])
        assert mamba_1 == mamba_2  # only the shared-attn KV grows
