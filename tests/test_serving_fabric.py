"""Fabric-served inference: session-sticky KV affinity, endpoint-level
continuous batching (decode coalescer), cache_bytes admission, failover
re-prefill, and the affinity_hint fallback regression."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import (
    Forwarder,
    FunctionService,
    MetricsRegistry,
    TaskEnvelope,
    TaskFuture,
)
from repro.core.containers import ContainerSpec
from repro.models.model import Model
from repro.serving.engine import ServeEngine
from repro.serving.fabric import (
    CacheAdmissionError,
    DecodeCoalescer,
    ModelHost,
    reset_serving,
    serve_model,
)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced("qwen1.5-0.5b").with_(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(autouse=True)
def _clean_specs():
    yield
    reset_serving()


def _greedy_reference(model, params, prompt, n_new):
    toks = list(np.asarray(prompt, np.int32))
    out = []
    for _ in range(n_new):
        h, _ = model.forward(params, {"tokens": jnp.asarray([toks], jnp.int32)})
        logits = model._logits(params, h)[0, -1]
        nxt = int(jnp.argmax(logits))
        out.append(nxt)
        toks.append(nxt)
    return out


def _fabric(model, params, n_endpoints=2, **serve_kw):
    svc = FunctionService()
    spec = ContainerSpec(
        name="jit", capabilities={"cpu", "jit"}, min_workers=0, max_workers=8
    )
    endpoints = [
        svc.make_endpoint(f"site{i}", n_executors=1, containers=[spec])
        for i in range(n_endpoints)
    ]
    serve_kw.setdefault("max_len", 48)
    serve_kw.setdefault("max_sessions", 6)
    client = serve_model(svc, model, params, name="qwen", **serve_kw)
    return svc, endpoints, client


# ---------------------------------------------------------------- tentpole
def test_fabric_generation_matches_reference(small_model):
    model, params = small_model
    svc, _, client = _fabric(model, params, n_endpoints=1)
    try:
        prompt = np.random.default_rng(0).integers(0, model.cfg.vocab, 6)
        toks = client.generate(prompt, max_new_tokens=5)
        assert toks == _greedy_reference(model, params, prompt, 5)
        snap = svc.metrics.snapshot()
        # 4 decode steps, all served from the resident cache slot
        assert snap["counters"]["serving.affinity_hits"] == 4
        assert snap["counters"]["serving.prefills"] == 1
        assert snap["histograms"]["serving.ttft_s"]["count"] == 1
    finally:
        svc.shutdown()


def test_concurrent_sessions_coalesce(small_model):
    model, params = small_model
    svc, _, client = _fabric(model, params, n_endpoints=1, window_s=0.05)
    try:
        results = {}

        def user(k, prompt):
            results[k] = client.generate(prompt, max_new_tokens=4)

        rng = np.random.default_rng(1)
        prompts = {k: rng.integers(0, model.cfg.vocab, 5) for k in range(4)}
        threads = [
            threading.Thread(target=user, args=(k, p)) for k, p in prompts.items()
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for k, p in prompts.items():
            assert results[k] == _greedy_reference(model, params, p, 4)
        snap = svc.metrics.snapshot()["counters"]
        decodes = snap["serving.affinity_hits"]  # 3 per session
        # continuous batching: fewer kernel invocations than decode tasks
        assert snap["serving.decode_batches"] < decodes
        assert svc.metrics.histogram("serving.merged_per_step").percentile(100) > 1
    finally:
        svc.shutdown()


def test_sessions_stick_to_one_endpoint(small_model):
    model, params = small_model
    svc, _, client = _fabric(model, params, n_endpoints=2)
    try:
        sessions = []

        def user(prompt):
            s = client.session(prompt)
            list(s.stream(4))
            sessions.append(s)

        rng = np.random.default_rng(2)
        threads = [
            threading.Thread(target=user, args=(rng.integers(0, model.cfg.vocab, 5),))
            for _ in range(4)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for s in sessions:
            assert len(set(s.endpoints)) == 1, s.endpoints  # sticky
            assert s.migrations == 0
        snap = svc.metrics.snapshot()["counters"]
        assert snap["forwarder.session_hits"] > 0
        assert snap.get("serving.cache_migrations", 0) == 0
    finally:
        svc.shutdown()


def test_session_failover_reprefills_on_survivor(small_model):
    model, params = small_model
    svc, endpoints, client = _fabric(model, params, n_endpoints=2)
    by_id = {e.endpoint_id: e for e in endpoints}
    try:
        prompt = np.random.default_rng(3).integers(0, model.cfg.vocab, 6)
        s = client.session(prompt)
        s.step()
        home = s.endpoints[-1]
        by_id[home].kill()
        assert home in svc.forwarder.check_endpoints()
        s.step()
        s.step()
        assert s.migrations == 1
        assert set(s.endpoints[-2:]) != {home}  # moved to the survivor
        assert s.tokens == _greedy_reference(model, params, prompt, 4)
        snap = svc.metrics.snapshot()["counters"]
        assert snap["serving.cache_migrations"] == 1
        assert snap["forwarder.session_evictions"] == 1
    finally:
        svc.shutdown()


def test_unbatched_host_matches_reference(small_model):
    """The per-request baseline path (private batch-1 caches) decodes the
    same tokens as the reference — the bench's 2x claim compares equals."""
    model, params = small_model
    host = ModelHost(model, params, max_len=48, max_sessions=2, batching=False)
    prompt = np.random.default_rng(4).integers(0, model.cfg.vocab, 6)
    toks = [host.prefill("s1", prompt)]
    history = list(prompt) + toks
    for _ in range(3):
        nxt, migrated = host.decode("s1", history)
        assert not migrated
        toks.append(nxt)
        history.append(nxt)
    assert toks == _greedy_reference(model, params, prompt, 4)


# ------------------------------------------------------------- admission
def test_cache_bytes_admission_control(small_model):
    from repro.serving.kv_cache import cache_bytes

    model, params = small_model
    per_seq = cache_bytes(model.cfg, 1, 48)
    metrics = MetricsRegistry()
    host = ModelHost(
        model, params, max_len=48, max_sessions=8,
        cache_bytes_budget=2 * per_seq, metrics=metrics,
    )
    assert host.n_slots == 2  # budget, not max_sessions, is the binding cap
    prompt = np.arange(4, dtype=np.int32)
    host.prefill("a", prompt)
    host.prefill("b", prompt)
    with pytest.raises(CacheAdmissionError):
        host.prefill("c", prompt)
    assert metrics.counter("serving.admission_rejects").value == 1
    assert host.release("a")
    host.prefill("c", prompt)  # freed slot admits the new session
    assert metrics.gauge("serving.cache_bytes").value == 2 * per_seq


# ------------------------------------------------------------- coalescer
def test_decode_coalescer_merges_concurrent_submits():
    calls = []
    barrier = threading.Barrier(4)

    def step(slots):
        calls.append(list(slots))
        time.sleep(0.01)
        return {s: 100 + s for s in slots}

    co = DecodeCoalescer(step, window_s=0.2, target_fn=lambda: 4)
    out = {}

    def submit(slot):
        barrier.wait()
        out[slot] = co.submit(slot)

    threads = [threading.Thread(target=submit, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert out == {0: 100, 1: 101, 2: 102, 3: 103}
    assert co.batches < 4  # at least one merged kernel invocation
    assert co.merged == 4
    assert max(len(c) for c in calls) > 1


def test_decode_coalescer_propagates_step_errors():
    def step(slots):
        raise RuntimeError("kernel exploded")

    co = DecodeCoalescer(step, window_s=0.01)
    with pytest.raises(RuntimeError, match="kernel exploded"):
        co.submit(0)
    # leadership must be released for the next caller
    co2 = DecodeCoalescer(lambda slots: {s: 7 for s in slots}, window_s=0.01)
    assert co2.submit(1) == 7


# ------------------------------------------------- site-aware dispatch
def test_site_aware_function_sees_its_endpoint():
    svc = FunctionService()
    eps = [svc.make_endpoint(f"s{i}", workers_per_executor=2) for i in range(2)]
    try:
        fid = svc.register_function(
            lambda _payload, site: site.endpoint_id, name="where", public=True,
            site_aware=True,
        )
        for ep in eps:
            assert svc.run(
                fid, None, endpoint_id=ep.endpoint_id, sync=True, timeout=10
            ) == ep.endpoint_id
    finally:
        svc.shutdown()


# ----------------------------------------- affinity / session routing
class FakeEndpoint:
    def __init__(self, eid, capacity=4, alive=True):
        self.endpoint_id = eid
        self._capacity = capacity
        self._alive = alive
        self.submitted = []

    def is_alive(self, max_heartbeat_age_s=None):
        return self._alive

    def capacity(self):
        return self._capacity

    def has_warm(self, key):
        return False

    def submit(self, env, future):
        self.submitted.append(env)


def _affinity_hits(fwd):
    return fwd.metrics.counter("forwarder.affinity_hits").value


def test_affinity_hint_falls_back_when_endpoint_dead():
    fwd = Forwarder(policy="least_outstanding", seed=0)
    dead, live = FakeEndpoint("dead", alive=False), FakeEndpoint("live")
    fwd.register(dead)
    fwd.register(live)
    try:
        env = TaskEnvelope(task_id="t0", function_id="f", payload=b"",
                           affinity_hint="dead")
        eid = fwd.submit(env, TaskFuture("t0"))
        assert eid == "live"
        assert _affinity_hits(fwd) == 0  # fallback must not count as a hit
    finally:
        fwd.shutdown()


def test_affinity_hint_falls_back_at_capacity():
    fwd = Forwarder(policy="least_outstanding", seed=0)
    a, b = FakeEndpoint("a", capacity=1), FakeEndpoint("b")
    fwd.register(a)
    fwd.register(b)
    try:
        # saturate a: one outstanding task == its full capacity
        fwd.submit(TaskEnvelope(task_id="t0", function_id="f", payload=b""),
                   TaskFuture("t0"), endpoint_id="a")
        env = TaskEnvelope(task_id="t1", function_id="f", payload=b"",
                           affinity_hint="a")
        eid = fwd.submit(env, TaskFuture("t1"))
        assert eid == "b"
        assert _affinity_hits(fwd) == 0
    finally:
        fwd.shutdown()


def test_pinned_submission_binds_session():
    fwd = Forwarder(policy="least_outstanding", seed=0)
    fwd.register(FakeEndpoint("a"))
    fwd.register(FakeEndpoint("b"))
    try:
        env = TaskEnvelope(task_id="t0", function_id="f", payload=b"",
                           session_id="sess")
        fwd.submit(env, TaskFuture("t0"), endpoint_id="b")
        # residency established: the next unpinned step follows the cache
        assert fwd.sessions.lookup("sess") == "b"
        env2 = TaskEnvelope(task_id="t1", function_id="f", payload=b"",
                            session_id="sess")
        assert fwd.submit(env2, TaskFuture("t1")) == "b"
    finally:
        fwd.shutdown()


def test_session_sticks_even_at_capacity_until_death():
    """Session affinity is harder than affinity_hint: saturation doesn't
    move a session (its KV slot is there); only death rebinds it."""
    fwd = Forwarder(policy="least_outstanding", seed=0)
    a, b = FakeEndpoint("a", capacity=1), FakeEndpoint("b", capacity=1)
    fwd.register(a)
    fwd.register(b)
    try:
        def sub(i):
            env = TaskEnvelope(task_id=f"t{i}", function_id="f", payload=b"",
                               session_id="sess")
            return fwd.submit(env, TaskFuture(f"t{i}"))

        home = sub(0)
        # futures never resolve: the home endpoint is saturated, yet the
        # session's tasks keep landing there
        assert sub(1) == home and sub(2) == home
        assert fwd.metrics.counter("forwarder.session_hits").value == 2
        (a if home == "a" else b)._alive = False
        assert home in fwd.check_endpoints()
        moved = sub(3)
        assert moved != home
        assert fwd.sessions.lookup("sess") == moved
        assert fwd.metrics.counter("forwarder.session_moves").value == 0
        assert fwd.metrics.counter("forwarder.session_evictions").value == 1
    finally:
        fwd.shutdown()


# ------------------------------------------------------- engine metrics
def test_engine_exports_serving_metrics(small_model):
    model, params = small_model
    metrics = MetricsRegistry()
    engine = ServeEngine(model, params, max_batch=2, max_len=32, metrics=metrics)
    rng = np.random.default_rng(5)
    for _ in range(2):
        engine.submit(rng.integers(0, model.cfg.vocab, 4), max_new_tokens=3)
    engine.run_until_drained(timeout=120)
    snap = metrics.snapshot()
    assert snap["histograms"]["serving.ttft_s"]["count"] == 2
    assert snap["counters"]["serving.tokens_generated"] == 6
    assert snap["counters"]["serving.decode_batches"] >= 2
    assert snap["gauges"]["serving.batch_occupancy"] is not None
