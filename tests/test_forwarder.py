"""Forwarder fabric tier: routing policies, capacity sharding, failover."""
import time

import pytest

from repro.core import Forwarder, FunctionService, TaskEnvelope, TaskFuture


class FakeEndpoint:
    def __init__(self, eid, capacity=4, warm=(), alive=True):
        self.endpoint_id = eid
        self._capacity = capacity
        self._warm = set(warm)
        self._alive = alive
        self.submitted = []

    def is_alive(self, max_heartbeat_age_s=None):
        return self._alive

    def capacity(self):
        return self._capacity

    def has_warm(self, key):
        return key in self._warm

    def submit(self, env, future):
        self.submitted.append(env)


def _env(i=0, fn="f"):
    return TaskEnvelope(task_id=f"t{i}", function_id=fn, payload=b"")


def _submit(fwd, ep_hint=None, i=0):
    fut = TaskFuture(f"t{i}")
    eid = fwd.submit(_env(i), fut, endpoint_id=ep_hint)
    return eid, fut


@pytest.fixture()
def fwd_factory():
    created = []

    def make(policy, endpoints, **kwargs):
        f = Forwarder(policy=policy, seed=0, **kwargs)
        for ep in endpoints:
            f.register(ep)
        created.append(f)
        return f

    yield make
    for f in created:
        f.shutdown()


# ---------------------------------------------------------------- routing
def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        Forwarder(policy="nope")

def test_least_outstanding_spreads_load(fwd_factory):
    a, b = FakeEndpoint("a"), FakeEndpoint("b")
    fwd = fwd_factory("least_outstanding", [a, b])
    picks = [_submit(fwd, i=i)[0] for i in range(4)]  # futures never complete
    assert sorted(picks) == ["a", "a", "b", "b"]


def test_least_outstanding_prefers_idle_endpoint(fwd_factory):
    a, b = FakeEndpoint("a"), FakeEndpoint("b")
    fwd = fwd_factory("least_outstanding", [a, b])
    eid0, fut0 = _submit(fwd, i=0)
    eid1, fut1 = _submit(fwd, i=1)
    fut1.set_result(None)  # the second endpoint is idle again
    eid2, _ = _submit(fwd, i=2)
    assert eid2 == eid1 != eid0


def test_latency_aware_prefers_fast_endpoint(fwd_factory):
    a, b = FakeEndpoint("a"), FakeEndpoint("b")
    fwd = fwd_factory("latency_aware", [a, b])
    fwd._records["a"].latency_ewma = 0.5
    fwd._records["b"].latency_ewma = 0.01
    assert fwd.choose(_env()).endpoint_id == "b"


def test_latency_aware_explores_unmeasured_first(fwd_factory):
    a, b = FakeEndpoint("a"), FakeEndpoint("b")
    fwd = fwd_factory("latency_aware", [a, b])
    fwd._records["a"].latency_ewma = 0.001  # fast, but b is unmeasured
    assert fwd.choose(_env()).endpoint_id == "b"


def test_warm_affinity_prefers_warm_endpoint(fwd_factory):
    cold = FakeEndpoint("cold")
    warm = FakeEndpoint("warm", warm=[("f", "default")])
    fwd = fwd_factory("warm_affinity", [cold, warm])
    assert fwd.choose(_env(fn="f")).endpoint_id == "warm"
    assert fwd.choose(_env(fn="other")).endpoint_id in ("cold", "warm")


def test_random_uses_all_endpoints(fwd_factory):
    eps = [FakeEndpoint(f"e{i}") for i in range(3)]
    fwd = fwd_factory("random", eps)
    picks = {fwd.choose(_env(i)).endpoint_id for i in range(60)}
    assert picks == {"e0", "e1", "e2"}


def test_dead_endpoints_excluded_from_routing(fwd_factory):
    a, b = FakeEndpoint("a", alive=False), FakeEndpoint("b")
    fwd = fwd_factory("random", [a, b])
    assert all(fwd.choose(_env(i)).endpoint_id == "b" for i in range(10))


def test_pinned_submit_goes_to_requested_endpoint(fwd_factory):
    a, b = FakeEndpoint("a"), FakeEndpoint("b")
    fwd = fwd_factory("least_outstanding", [a, b])
    for i in range(3):
        eid, _ = _submit(fwd, ep_hint="b", i=i)
        assert eid == "b"
    assert len(b.submitted) == 3 and not a.submitted
    with pytest.raises(KeyError):
        _submit(fwd, ep_hint="nope")


def test_no_live_endpoint_raises(fwd_factory):
    fwd = fwd_factory("random", [FakeEndpoint("a", alive=False)])
    with pytest.raises(RuntimeError):
        _submit(fwd)


# ---------------------------------------------------------------- sharding
def test_shard_proportional_to_capacity(fwd_factory):
    a = FakeEndpoint("a", capacity=2)
    b = FakeEndpoint("b", capacity=6)
    fwd = fwd_factory("random", [a, b])
    alloc = dict(fwd.shard(8))
    assert alloc == {"a": 2, "b": 6}
    # remainders are distributed and the allocation always covers n
    for n in (1, 3, 7, 100):
        assert sum(c for _, c in fwd.shard(n)) == n


def test_shard_skips_dead_endpoints(fwd_factory):
    a = FakeEndpoint("a", capacity=4, alive=False)
    b = FakeEndpoint("b", capacity=4)
    fwd = fwd_factory("random", [a, b])
    assert dict(fwd.shard(6)) == {"b": 6}


def test_map_shards_by_advertised_capacity():
    svc = FunctionService()
    big = svc.make_endpoint("big", n_executors=3, workers_per_executor=2)
    small = svc.make_endpoint("small", n_executors=1, workers_per_executor=2)

    def ident(doc):
        return doc

    fid = svc.register_function(ident)
    outs = svc.map(fid, [{"i": i} for i in range(8)], timeout=30)
    assert [o["i"] for o in outs] == list(range(8))  # order preserved
    routed = svc.forwarder.stats()["endpoints"]
    assert routed[big.endpoint_id]["routed"] == 6
    assert routed[small.endpoint_id]["routed"] == 2
    svc.shutdown()


# ---------------------------------------------------------------- failover
def _sleepy(doc):
    time.sleep(doc.get("t", 0.03))
    return {"i": doc.get("i", -1)}


def test_endpoint_death_fails_over_to_survivor():
    svc = FunctionService(policy="least_outstanding")
    svc.forwarder.liveness_threshold_s = 0.2
    svc.forwarder.watchdog_interval_s = 0.02
    ep_a = svc.make_endpoint("fo-a", n_executors=1, workers_per_executor=2)
    svc.make_endpoint("fo-b", n_executors=1, workers_per_executor=2)
    fid = svc.register_function(_sleepy)
    futs = [svc.run(fid, {"i": i, "t": 0.08}) for i in range(10)]
    time.sleep(0.05)
    ep_a.kill()
    results = [f.result(timeout=30) for f in futs]
    assert sorted(r["i"] for r in results) == list(range(10))
    assert svc.forwarder.failovers > 0
    assert svc.forwarder.stats()["endpoints"][ep_a.endpoint_id]["dead"]
    svc.shutdown()


def test_death_with_no_survivor_raises():
    svc = FunctionService()
    svc.forwarder.liveness_threshold_s = 0.2
    svc.forwarder.watchdog_interval_s = 0.02
    ep = svc.make_endpoint("solo", n_executors=1, workers_per_executor=1)
    fid = svc.register_function(_sleepy)
    fut = svc.run(fid, {"i": 0, "t": 0.5})
    ep.kill()
    with pytest.raises(RuntimeError, match="lost"):
        fut.result(timeout=10)
    assert svc.forwarder.orphaned == 1
    svc.shutdown()


def test_false_positive_death_resurrects_on_fresh_heartbeat():
    svc = FunctionService()
    svc.forwarder.watchdog_interval_s = 0.01
    ep = svc.make_endpoint("fp", n_executors=1, workers_per_executor=1)
    fid = svc.register_function(_sleepy)
    svc.run(fid, {"i": 0, "t": 0.0}).result(10)
    svc.forwarder.liveness_threshold_s = 1e-9  # every endpoint looks dead
    deadline = time.monotonic() + 2
    while not svc.forwarder.stats()["endpoints"][ep.endpoint_id]["dead"]:
        assert time.monotonic() < deadline, "watchdog never marked endpoint dead"
        time.sleep(0.01)
    svc.forwarder.liveness_threshold_s = 2.0  # heartbeat is fresh again
    deadline = time.monotonic() + 2
    while svc.forwarder.stats()["endpoints"][ep.endpoint_id]["dead"]:
        assert time.monotonic() < deadline, "endpoint was never resurrected"
        time.sleep(0.01)
    out = svc.run(fid, {"i": 7, "t": 0.0}, sync=True, timeout=10)
    assert out["i"] == 7
    svc.shutdown()


def test_latency_ewma_recorded_after_completion():
    svc = FunctionService(policy="latency_aware")
    ep = svc.make_endpoint("lat", n_executors=1, workers_per_executor=2)
    fid = svc.register_function(_sleepy)
    svc.map(fid, [{"i": i, "t": 0.005} for i in range(4)], timeout=30)
    rec = svc.forwarder.stats()["endpoints"][ep.endpoint_id]
    assert rec["completed"] == 4
    assert rec["latency_ewma_s"] is not None and rec["latency_ewma_s"] > 0
    svc.shutdown()
