"""Data fabric tier: object stores, DataRef spill/resolve, the process-global
store registry, and the service-level put_data/fetch surface."""
import os

import numpy as np
import pytest

from repro.core import (
    DataRef,
    FileSystemStore,
    FunctionService,
    InMemoryStore,
    MetricsRegistry,
    get_store,
    packb,
    payload_hash,
    register_store,
    reset_store_registry,
    resolve_packed,
    resolve_payload,
    scan_refs,
    spill_payload,
    unpackb,
)
from repro.core.datastore import deregister_store


@pytest.fixture(autouse=True)
def _clean_registry():
    reset_store_registry()
    yield
    reset_store_registry()


# ---------------------------------------------------------------- stores
@pytest.mark.parametrize("make", [
    lambda tmp: InMemoryStore(),
    lambda tmp: FileSystemStore(os.path.join(tmp, "blobs")),
])
def test_store_roundtrip_and_content_addressing(make, tmp_path):
    store = make(str(tmp_path))
    key = store.put(b"hello fabric")
    assert key == store.content_key(b"hello fabric")
    assert key in store
    assert store.get(key) == b"hello fabric"
    # idempotent: the same bytes land on the same key, accounting unchanged
    assert store.put(b"hello fabric") == key
    assert len(store) == 1
    assert store.total_bytes() == len(b"hello fabric")
    assert store.delete(key)
    assert key not in store
    assert not store.delete(key)


def test_store_get_missing_key_raises(tmp_path):
    store = FileSystemStore(str(tmp_path / "s"))
    with pytest.raises(KeyError):
        store.get("0" * 64)


def test_filesystem_store_rejects_traversal_keys(tmp_path):
    store = FileSystemStore(str(tmp_path / "s"))
    for bad in ("../escape", "a/b", "..", "."):
        with pytest.raises(ValueError):
            store.put(b"x", key=bad)


def test_filesystem_store_survives_reopen(tmp_path):
    d = str(tmp_path / "s")
    key = FileSystemStore(d).put(b"persisted")
    reopened = FileSystemStore(d)
    assert reopened.get(key) == b"persisted"
    assert reopened.keys() == [key]


def test_lithops_shaped_aliases(tmp_path):
    store = InMemoryStore()
    store.put_object("k1", b"body")
    assert store.get_object("k1") == b"body"
    head = store.head_object("k1")
    assert head["size"] == len(b"body")
    assert store.list_keys() == ["k1"]
    assert store.delete_object("k1")


def test_store_metrics_gauges():
    m = MetricsRegistry()
    store = InMemoryStore(store_id="mem://gauged")
    store.bind_metrics(m)
    store.put(b"x" * 100)
    labels = {"store": "mem://gauged"}
    assert m.gauge("data.objects", labels).value == 1
    assert m.gauge("data.store_bytes", labels).value == 100


# ---------------------------------------------------------------- registry
def test_registry_register_get_close(tmp_path):
    store = InMemoryStore(store_id="mem://reg")
    assert get_store("mem://reg") is store
    store.close()
    with pytest.raises(KeyError):
        get_store("mem://reg")


def test_fs_store_auto_attaches_by_path(tmp_path):
    """The crash-restart path: a fresh process holds no registry entries, but
    fs:// ids re-attach by directory so journaled refs stay resolvable."""
    d = str(tmp_path / "s")
    store = FileSystemStore(d)
    key = store.put(b"durable blob")
    sid = store.store_id
    reset_store_registry()  # simulated process restart
    attached = get_store(sid)
    assert attached.get(key) == b"durable blob"
    deregister_store(sid)
    register_store(attached)  # explicit re-register is also fine
    assert get_store(sid) is attached


def test_unknown_mem_store_is_gone_after_reset():
    sid = InMemoryStore().store_id
    reset_store_registry()
    with pytest.raises(KeyError):
        get_store(sid)


# ---------------------------------------------------------------- spill
def test_spill_replaces_only_large_leaves():
    store = InMemoryStore()
    big = np.zeros(1024, dtype=np.float64)   # 8 KiB
    small = np.arange(4, dtype=np.int32)     # 16 B
    payload = {"big": big, "small": small, "meta": {"n": 7}}
    spilled, refs = spill_payload(payload, store, threshold=4096)
    assert isinstance(spilled["big"], DataRef)
    assert spilled["big"].size == len(packb(big))  # blob (wire) size
    assert isinstance(spilled["small"], np.ndarray)
    assert spilled["meta"] == {"n": 7}
    assert [r.key for r in refs] == [spilled["big"].key]
    resolved = resolve_payload(spilled)
    np.testing.assert_array_equal(resolved["big"], big)


def test_spill_collects_preexisting_refs():
    store = InMemoryStore()
    ref = DataRef(key=store.put(packb([1, 2, 3])), size=8,
                  locations=(store.store_id,))
    _, refs = spill_payload({"x": ref}, store, threshold=1 << 30)
    assert refs == [ref]
    assert scan_refs([{"deep": [ref]}]) == [ref]


def test_resolve_packed_uses_locality_cache():
    m = MetricsRegistry()
    store = InMemoryStore()
    cache = InMemoryStore(register=False)
    big = np.ones(4096, dtype=np.float32)
    spilled, _ = spill_payload({"x": big}, store, threshold=1024)
    packed = packb(spilled)
    first = unpackb(resolve_packed(packed, cache=cache, metrics=m))
    second = unpackb(resolve_packed(packed, cache=cache, metrics=m))
    np.testing.assert_array_equal(first["x"], big)
    np.testing.assert_array_equal(second["x"], big)
    assert m.counter("data.cache_misses").value == 1
    assert m.counter("data.cache_hits").value == 1


def test_decoded_cache_decodes_once_and_isolates_mutation():
    """The endpoint-level decoded-value cache: one msgpack decode per blob,
    every task gets a fresh copy, so mutating a handed-out value never leaks
    into later resolutions."""
    m = MetricsRegistry()
    store = InMemoryStore()
    arr = np.arange(4096, dtype=np.int64)
    spilled, _ = spill_payload({"x": arr}, store, threshold=1024)
    ref = spilled["x"]
    decoded = {}
    first = resolve_payload(spilled, metrics=m, decoded=decoded)
    first["x"][:] = -1  # a task scribbling on its payload
    second = resolve_payload(spilled, metrics=m, decoded=decoded)
    np.testing.assert_array_equal(second["x"], arr)
    assert second["x"] is not first["x"]
    assert ref.key in decoded
    assert m.counter("data.decoded_hits").value == 1
    assert m.counter("data.resolved_refs").value == 2


def test_resolve_unresolvable_ref_raises():
    orphan = DataRef(key="f" * 64, size=10, locations=("mem://nowhere",))
    with pytest.raises(KeyError):
        resolve_payload({"x": orphan})


# ---------------------------------------------------------------- hashing
def test_payload_hash_ignores_ref_locations():
    """Memoization keys must survive data movement: the same blob advertised
    from different stores hashes identically."""
    a = DataRef(key="a" * 64, size=128, locations=("mem://one",))
    b = DataRef(key="a" * 64, size=128, locations=("fs:///two", "mem://three"))
    assert payload_hash({"x": a, "n": 1}) == payload_hash({"x": b, "n": 1})
    c = DataRef(key="b" * 64, size=128, locations=("mem://one",))
    assert payload_hash({"x": a}) != payload_hash({"x": c})


def test_dataref_serializer_roundtrip():
    ref = DataRef(key="c" * 64, size=42, locations=("mem://x", "fs:///y"))
    out = unpackb(packb({"nested": [ref], "top": ref}))
    assert out["nested"][0] == ref
    assert out["top"] == ref
    assert out["top"].locations == ("mem://x", "fs:///y")


# ---------------------------------------------------------------- service
def double(doc):
    return {"y": np.asarray(doc["x"]) * 2.0}


def test_service_put_data_fetch_roundtrip(tmp_path):
    svc = FunctionService(
        datastore=FileSystemStore(str(tmp_path / "blobs")),
        spill_threshold=1024,
    )
    svc.make_endpoint("d0", n_executors=1, workers_per_executor=2)
    fid = svc.register_function(double, name="fabric_double")
    try:
        x = np.arange(2048, dtype=np.float64)
        ref = svc.put_data(x)
        assert isinstance(ref, DataRef)
        out = svc.run(fid, {"x": ref}).result(30)
        # the oversized result came back as a ref; fetch materializes it
        assert isinstance(out["y"], DataRef)
        np.testing.assert_array_equal(svc.fetch(out)["y"], x * 2.0)
        assert svc.metrics.counter("data.spilled_leaves").value >= 1
        assert svc.metrics.counter("data.resolved_refs").value >= 1
    finally:
        svc.shutdown()


def test_service_without_datastore_rejects_put_data():
    svc = FunctionService()
    try:
        with pytest.raises(ValueError):
            svc.put_data(b"x" * 10)
    finally:
        svc.shutdown()


def test_small_payloads_never_spill(tmp_path):
    svc = FunctionService(
        datastore=FileSystemStore(str(tmp_path / "blobs")),
        spill_threshold=1 << 20,
    )
    svc.make_endpoint("d1", n_executors=1, workers_per_executor=1)
    fid = svc.register_function(double, name="fabric_double_small")
    try:
        out = svc.run(fid, {"x": np.arange(8, dtype=np.float64)}).result(30)
        np.testing.assert_array_equal(out["y"], np.arange(8) * 2.0)
        assert svc.metrics.counter("data.spilled_leaves").value == 0
    finally:
        svc.shutdown()
