"""Telemetry layer: instrument semantics, registry behaviour, and the
fabric-wide snapshot produced by a real FunctionService run."""
import threading

from repro.core import (
    SIZE_BUCKETS,
    FunctionService,
    Histogram,
    MetricsRegistry,
    merged_snapshot,
)


# ---------------------------------------------------------------- instruments
def test_counter_and_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("service.tasks_submitted")
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = reg.gauge("endpoint.queue_depth")
    assert g.value is None  # unset != zero (unmeasured endpoints explore first)
    g.set(3)
    g.inc()
    g.dec(2)
    assert g.value == 2.0


def test_counter_thread_safety():
    reg = MetricsRegistry()

    def worker():
        for _ in range(1000):
            reg.counter("c").inc()

    threads = [threading.Thread(target=worker) for _ in range(8)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    assert reg.counter("c").value == 8000


def test_histogram_buckets_and_percentiles():
    h = Histogram("lat", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    assert h.count == 5
    assert abs(h.sum - 5.56) < 1e-9
    d = h.to_dict()
    assert d["buckets"] == {"0.01": 2, "0.1": 1, "1.0": 1, "+inf": 1}
    # p50 falls in the (0.01, 0.1] bucket; interpolation stays inside it
    p50 = h.percentile(50)
    assert 0.01 <= p50 <= 0.1
    assert h.percentile(100) >= 1.0


def test_histogram_empty_percentile_is_none():
    h = Histogram("empty")
    assert h.percentile(50) is None
    assert h.mean() is None


def test_registry_get_or_create_and_labels():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    a = reg.gauge("forwarder.endpoint_outstanding", {"endpoint": "a"})
    b = reg.gauge("forwarder.endpoint_outstanding", {"endpoint": "b"})
    assert a is not b
    a.set(1)
    b.set(2)
    fam = reg.family("forwarder.endpoint_outstanding")
    assert sorted(fam.values()) == [1.0, 2.0]


def test_export_text_prometheus_shape():
    reg = MetricsRegistry()
    reg.counter("service.tasks_submitted").inc(3)
    reg.gauge("endpoint.queue_depth", {"endpoint": "ep0"}).set(7)
    reg.histogram("service.e2e_latency_s").observe(0.02)
    reg.counter("forwarder.routing_decisions", {"policy": "random"}).inc(2)
    text = reg.export_text()
    assert "service_tasks_submitted_total 3" in text
    assert 'endpoint_queue_depth{endpoint="ep0"} 7.0' in text
    assert "service_e2e_latency_s_count 1" in text
    # suffix precedes the labels, or Prometheus rejects the line
    assert 'forwarder_routing_decisions_total{policy="random"} 2' in text


def test_merged_snapshot_unions_registries():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("x").inc()
    b.counter("y").inc(2)
    merged = merged_snapshot([a, b])
    assert merged["counters"] == {"x": 1, "y": 2}


# ---------------------------------------------------------------- integration
def _noop(doc):
    return doc


def test_snapshot_from_map_run_reports_fabric_telemetry():
    """Acceptance: non-zero submit/complete counters and latency histograms
    from a FunctionService.map() run, in one shared registry."""
    svc = FunctionService()
    svc.make_endpoint("m0", n_executors=2, workers_per_executor=2, prefetch=2)
    fid = svc.register_function(_noop, name="noop")
    outs = svc.map(fid, [{"i": i} for i in range(16)], timeout=60)
    assert len(outs) == 16
    snap = svc.metrics.snapshot()
    c = snap["counters"]
    assert c["service.tasks_submitted"] >= 16
    assert c["service.tasks_completed"] >= 16
    assert c["forwarder.tasks_routed"] >= 16
    assert c["forwarder.batches_delivered"] >= 1
    assert c["endpoint.tasks_completed"] >= 16
    assert c["executor.tasks_executed"] >= 16
    assert c["warming.cold_starts"] >= 1
    h = snap["histograms"]
    assert h["service.e2e_latency_s"]["count"] >= 16
    assert h["service.e2e_latency_s"]["p95"] is not None
    assert h["executor.service_time_s"]["count"] >= 16
    assert h["endpoint.dispatch_latency_s"]["count"] >= 16
    assert h["forwarder.batch_size"]["count"] >= 1
    svc.shutdown()


def test_memo_hits_counted():
    svc = FunctionService()
    svc.make_endpoint("memo", n_executors=1, workers_per_executor=1)
    fid = svc.register_function(_noop, name="noop")
    svc.run(fid, {"k": 1}, memoize=True, sync=True, timeout=30)
    svc.run(fid, {"k": 1}, memoize=True, sync=True, timeout=30)
    snap = svc.metrics.snapshot()
    assert snap["counters"].get("service.memo_hits", 0) >= 1
    svc.shutdown()


def test_failed_tasks_counted():
    svc = FunctionService()
    svc.make_endpoint("fail", n_executors=1, workers_per_executor=1)

    def boom(doc):
        raise RuntimeError("boom")

    fid = svc.register_function(boom)
    fut = svc.run(fid, {}, max_retries=0)
    try:
        fut.result(30)
    except RuntimeError:
        pass
    snap = svc.metrics.snapshot()
    assert snap["counters"].get("service.tasks_failed", 0) >= 1
    svc.shutdown()


def test_warm_hits_counted_across_repeat_invocations():
    svc = FunctionService()
    svc.make_endpoint("warm", n_executors=1, workers_per_executor=1)
    fid = svc.register_function(_noop, name="noop")
    for i in range(4):
        svc.run(fid, {"i": i}, sync=True, timeout=30)
    snap = svc.metrics.snapshot()
    assert snap["counters"].get("warming.warm_hits", 0) >= 1
    svc.shutdown()


class _FakeEndpoint:
    def __init__(self, eid):
        self.endpoint_id = eid

    def is_alive(self, max_heartbeat_age_s=None):
        return True

    def capacity(self):
        return 4

    def submit(self, env, future):
        pass

    def shutdown(self):
        pass


def test_reregistered_endpoint_is_unmeasured_again():
    """A deregistered endpoint that rejoins must be explored afresh by
    latency_aware routing, not shunned on a stale EWMA gauge."""
    from repro.core import Forwarder

    fwd = Forwarder()
    ep = _FakeEndpoint("ep-rejoin")
    fwd.register(ep)
    fwd._records["ep-rejoin"].latency_ewma = 0.7
    fwd.deregister("ep-rejoin")
    fwd.register(ep)
    assert fwd._records["ep-rejoin"].latency_ewma is None
    fwd.shutdown()


def test_service_rebinds_prebuilt_forwarder_onto_explicit_registry():
    """Adopting a pre-built forwarder under an explicit registry must move
    already-registered records over — one fabric, one registry."""
    from repro.core import Forwarder

    fwd = Forwarder()
    fwd.register(_FakeEndpoint("ep-early"))
    mine = MetricsRegistry()
    svc = FunctionService(forwarder=fwd, metrics=mine)
    assert svc.metrics is mine and fwd.metrics is mine
    fwd._records["ep-early"].latency_ewma = 0.2
    assert mine.family("forwarder.endpoint_latency_ewma_s") == {
        "forwarder.endpoint_latency_ewma_s{endpoint=ep-early}": 0.2
    }
    svc.shutdown()


def test_forwarder_batch_size_uses_size_buckets():
    svc = FunctionService()
    svc.make_endpoint("bb", n_executors=1, workers_per_executor=2, prefetch=2)
    fid = svc.register_function(_noop, name="noop")
    futs = svc.batch_run(fid, [{"i": i} for i in range(10)])
    [f.result(30) for f in futs]
    h = svc.metrics.histogram("forwarder.batch_size", buckets=SIZE_BUCKETS)
    assert h.count >= 1
    # a 10-task batch lands in the (8, 16] bucket
    assert any(float(k) >= 10 for k in h.to_dict()["buckets"] if k != "+inf")
    svc.shutdown()
