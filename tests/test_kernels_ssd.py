"""Pallas SSD kernel vs pure-jnp oracle + independent sequential recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd import ref
from repro.kernels.ssd.kernel import ssd_pallas

TOL = {jnp.float32: 1e-4, jnp.bfloat16: 5e-2}


def _inputs(key, B, S, H, P, G, N, dtype):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N), dtype) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N), dtype) * 0.3
    return x, dt, A, Bm, Cm


def _sequential_oracle(x, dt, A, Bm, Cm):
    """Literal per-token recurrence — an oracle independent of the chunked
    math shared by ref and kernel."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    ys = []
    state = jnp.zeros((B, H, P, N), jnp.float32)
    for t in range(S):
        y, state = ref.ssd_decode_reference(
            state, x[:, t].astype(jnp.float32), dt[:, t], A, Bm[:, t], Cm[:, t]
        )
        ys.append(y)
    return jnp.stack(ys, axis=1), state


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,P,G,N,chunk",
    [
        (1, 64, 2, 16, 1, 16, 16),
        (2, 128, 4, 32, 2, 8, 32),
        (1, 96, 6, 16, 1, 32, 32),   # S not a power of two (3 chunks)
        (2, 64, 8, 64, 4, 16, 64),   # single chunk
    ],
)
def test_ssd_kernel_matches_ref(B, S, H, P, G, N, chunk, dtype, key):
    x, dt, A, Bm, Cm = _inputs(key, B, S, H, P, G, N, dtype)
    y_k, st_k = ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk, return_final_state=True,
                           interpret=True)
    y_r, st_r = ref.ssd_reference(x, dt, A, Bm, Cm, chunk=chunk,
                                  return_final_state=True)
    tol = TOL[dtype]
    np.testing.assert_allclose(y_k.astype(jnp.float32), y_r.astype(jnp.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(st_k, st_r, rtol=tol, atol=tol)


def test_ssd_ref_matches_sequential_recurrence(key):
    x, dt, A, Bm, Cm = _inputs(key, 1, 32, 2, 8, 1, 8, jnp.float32)
    y_r, st_r = ref.ssd_reference(x, dt, A, Bm, Cm, chunk=8, return_final_state=True)
    y_s, st_s = _sequential_oracle(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y_r, y_s.astype(y_r.dtype), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st_r, st_s, rtol=1e-4, atol=1e-4)


def test_ssd_kernel_matches_sequential_recurrence(key):
    x, dt, A, Bm, Cm = _inputs(key, 2, 48, 4, 16, 2, 8, jnp.float32)
    y_k, st_k = ssd_pallas(x, dt, A, Bm, Cm, chunk=16, return_final_state=True,
                           interpret=True)
    y_s, st_s = _sequential_oracle(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y_k, y_s.astype(y_k.dtype), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st_k, st_s, rtol=1e-4, atol=1e-4)


def test_ssd_chunk_invariance(key):
    x, dt, A, Bm, Cm = _inputs(key, 1, 64, 2, 16, 1, 16, jnp.float32)
    outs = [
        ssd_pallas(x, dt, A, Bm, Cm, chunk=c, interpret=True)[0] for c in (8, 16, 32, 64)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-4)


def test_ssd_initial_state_continuation(key):
    """Splitting a sequence and carrying the state must equal one long scan
    (the prefill -> decode handoff invariant)."""
    x, dt, A, Bm, Cm = _inputs(key, 1, 64, 2, 16, 1, 16, jnp.float32)
    y_full, st_full = ref.ssd_reference(x, dt, A, Bm, Cm, chunk=16,
                                        return_final_state=True)
    y1, st1 = ref.ssd_reference(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32],
                                chunk=16, return_final_state=True)
    y2, st2 = ref.ssd_reference(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:],
                                chunk=16, initial_state=st1, return_final_state=True)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(st2, st_full, rtol=1e-4, atol=1e-4)
