"""Dry-run machinery on a small forced-device mesh, in a subprocess (the
XLA_FLAGS device-count override must not leak into this test process)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(tmp_path, *args):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--results", str(tmp_path / "r.json"), *args],
        capture_output=True, text=True, env=env, timeout=900, cwd=REPO,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    with open(tmp_path / "r.json") as f:
        return json.load(f)


@pytest.mark.slow
def test_dryrun_small_mesh_train(tmp_path):
    res = _run_dryrun(
        tmp_path, "--arch", "qwen1.5-0.5b", "--shape", "train_4k", "--mesh", "2,4",
    )
    rec = list(res.values())[0]
    assert rec["status"] == "ok", rec.get("error")
    a = rec["analysis"]
    assert a["memory"]["resident_bytes"] > 0
    r = a["roofline"]
    assert r["compute_s"] > 0 and r["collective_s"] >= 0
    assert r["bottleneck"] in ("compute", "memory", "collective")
    assert 0 < r["useful_flops_ratio"] <= 1.5


@pytest.mark.slow
def test_dryrun_small_mesh_decode(tmp_path):
    res = _run_dryrun(
        tmp_path, "--arch", "qwen2-0.5b", "--shape", "decode_32k", "--mesh", "2,4",
    )
    rec = list(res.values())[0]
    assert rec["status"] == "ok", rec.get("error")


def test_collective_parser_units():
    from repro.launch.analysis import parse_collectives

    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag.1 = bf16[64,512]{1,0} all-gather(%y), replica_groups=[2,4]<=[8], dimensions={1}
  %rs = f32[32]{0} reduce-scatter(%z), replica_groups={{0,1}}, dimensions={0}
  %cp = collective-permute-start(%w), source_target_pairs={{0,1}}
  %single = f32[8]{0} all-reduce(%q), replica_groups={{0}}, to_apply=%add
"""
    stats = parse_collectives(hlo)
    assert stats.counts["all-reduce"] == 1  # single-participant one excluded
    assert stats.counts["all-gather"] == 1
    assert stats.counts["reduce-scatter"] == 1
    ar_bytes = 128 * 256 * 4
    assert stats.result_bytes["all-reduce"] == ar_bytes
    assert stats.wire_bytes["all-reduce"] == pytest.approx(2 * ar_bytes * 3 / 4)
    ag_bytes = 64 * 512 * 2
    assert stats.wire_bytes["all-gather"] == pytest.approx(ag_bytes * 3 / 4)
    rs_bytes = 32 * 4
    assert stats.wire_bytes["reduce-scatter"] == pytest.approx(rs_bytes * 1)


def test_model_flops_accounting():
    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import model_flops

    cfg = get_config("deepseek-67b")
    t = model_flops(cfg, SHAPES["train_4k"])
    assert t == pytest.approx(6 * cfg.param_count() * 4096 * 256, rel=1e-6)
    d = model_flops(cfg, SHAPES["decode_32k"])
    assert d == pytest.approx(2 * cfg.param_count() * 128, rel=1e-6)


def test_cell_applicability_rules():
    from repro.configs import SHAPES, cell_applicable, get_config

    ok, _ = cell_applicable(get_config("mamba2-2.7b"), SHAPES["long_500k"])
    assert ok
    ok, reason = cell_applicable(get_config("deepseek-67b"), SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in reason
    ok, _ = cell_applicable(get_config("whisper-small"), SHAPES["decode_32k"])
    assert ok  # enc-dec decodes; only encoder-only archs would skip


@pytest.mark.slow
def test_local_moe_shard_map_matches_global_on_fake_mesh(tmp_path):
    """8 forced devices: moe_impl=local (shard_map) must equal moe_impl=global."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod
from repro.sharding import partition
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(3)
cfg = get_reduced("qwen3-moe-235b-a22b").with_(dtype="float32", d_model=8)
m = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, capacity_factor=8.0)
p, _ = moe_mod.init_moe(key, cfg.with_(moe=m))
x = jax.random.normal(key, (4, 16, 8), jnp.float32)
with partition.use_mesh(mesh):
    yg, _ = jax.jit(lambda x, p: moe_mod.moe_ffn(x, p, cfg.with_(moe=m, moe_impl="global")))(x, p)
    yl, _ = jax.jit(lambda x, p: moe_mod.moe_ffn(x, p, cfg.with_(moe=m, moe_impl="local")))(x, p)
assert jnp.allclose(yg, yl, atol=1e-5), float(jnp.max(jnp.abs(yg - yl)))
print("LOCAL_MOE_OK")
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=600, cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "LOCAL_MOE_OK" in out.stdout
