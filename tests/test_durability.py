"""Durability tier: write-ahead journal, exactly-once results, resume.

Covers the three headline guarantees:
- a crash during append leaves a truncated record that replay skips,
- duplicated result delivery resolves each future exactly once, and
- resume after a fabric kill re-runs only incomplete work (standalone
  tasks and DAG nodes alike).
"""
from __future__ import annotations

import os
import threading

import pytest

from repro.core import (
    Forwarder,
    FunctionService,
    Journal,
    ResultStore,
    TaskFuture,
    Workflow,
    WorkflowNode,
    serializer,
)

# Module-level functions: their ids are source-content hashes, so a second
# fabric registering the same source sees the same function_id — the identity
# contract resume depends on. The executed-node log lets tests assert which
# work actually re-ran.
EXECUTED: list = []
_EXECUTED_LOCK = threading.Lock()


def tracked_inc(x):
    with _EXECUTED_LOCK:
        EXECUTED.append(x)
    return x + 1


def plain_double(x):
    return x * 2


@pytest.fixture(autouse=True)
def _clear_executed():
    with _EXECUTED_LOCK:
        EXECUTED.clear()
    yield


# ---------------------------------------------------------------------------
# Journal framing / replay
# ---------------------------------------------------------------------------
class TestJournalFraming:
    def test_append_replay_roundtrip(self, tmp_path):
        j = Journal(str(tmp_path))
        j.append("task", "submitted", task_id="t1", function_id="f1")
        j.append("task", "completed", task_id="t1", value=serializer.packb(7))
        recs = list(j.records())
        assert [r["event"] for r in recs] == ["submitted", "completed"]
        st = j.state()
        assert st.tasks["t1"].status == "completed"
        assert st.tasks["t1"].result() == 7
        j.close()

    def test_crash_during_append_truncated_record_skipped(self, tmp_path):
        j = Journal(str(tmp_path))
        j.append("task", "submitted", task_id="t1", function_id="f1")
        j.append("task", "submitted", task_id="t2", function_id="f1")
        seg = j.segments()[-1]
        j.close()
        # crash mid-append: the tail record loses its last bytes
        size = os.path.getsize(seg)
        with open(seg, "ab") as f:
            f.truncate(size - 3)
        j2 = Journal(str(tmp_path))
        recs = list(j2.records())
        assert [r["task_id"] for r in recs] == ["t1"]  # torn tail skipped
        assert j2.metrics.counter("journal.truncated_records").value == 1
        # the torn segment is quarantined: new appends land in a fresh
        # segment and replay still stops at the tear
        j2.append("task", "submitted", task_id="t3", function_id="f1")
        assert [r["task_id"] for r in j2.records()] == ["t1", "t3"]
        j2.close()

    def test_garbage_tail_terminates_segment(self, tmp_path):
        j = Journal(str(tmp_path))
        j.append("run", "started", run_id="r1", workflow="w", nodes=["a"])
        seg = j.segments()[-1]
        j.close()
        with open(seg, "ab") as f:
            f.write(b"\x00garbage-not-a-frame")
        j2 = Journal(str(tmp_path))
        assert [r["event"] for r in j2.records()] == ["started"]
        j2.close()

    def test_closed_journal_drops_appends(self, tmp_path):
        j = Journal(str(tmp_path))
        j.append("task", "submitted", task_id="t1")
        j.close()
        assert j.append("task", "completed", task_id="t1") is None
        j2 = Journal(str(tmp_path))
        assert not j2.state().tasks["t1"].terminal
        j2.close()

    def test_compaction_folds_history_and_gcs_segments(self, tmp_path):
        j = Journal(str(tmp_path))
        for i in range(20):
            j.append("task", "submitted", task_id=f"t{i}", function_id="f",
                     payload=serializer.packb(i))
            j.append("task", "completed", task_id=f"t{i}",
                     value=serializer.packb(i))
        j.append("task", "submitted", task_id="open", function_id="f",
                 payload=serializer.packb(0))
        before = j.state()
        j.compact()
        assert len(j.segments()) <= 2  # snapshot + fresh active segment
        after = j.state()
        assert set(after.tasks) == set(before.tasks)
        assert after.tasks["t7"].result() == 7
        assert not after.tasks["open"].terminal
        assert j.metrics.counter("journal.compactions").value == 1
        j.close()

    def test_duplicate_terminal_records_counted_once(self, tmp_path):
        j = Journal(str(tmp_path))
        j.append("task", "completed", task_id="t1", value=serializer.packb(1))
        j.append("task", "completed", task_id="t1", value=serializer.packb(2))
        j.append("task", "failed", task_id="t1", error="late loser")
        st = j.state()
        assert st.duplicate_completions == 2
        assert st.tasks["t1"].result() == 1  # first commitment wins
        j.close()


# ---------------------------------------------------------------------------
# Exactly-once result delivery
# ---------------------------------------------------------------------------
class TestExactlyOnce:
    def test_result_store_dedupes_and_counts(self):
        store = ResultStore()
        assert store.record("t1", value=1) is True
        assert store.record("t1", value=2) is False
        assert store.record("t1", error=RuntimeError("x")) is False
        assert store.metrics.counter("journal.duplicate_results").value == 2
        store.prime("t2")  # replay seeding never counts as a duplicate
        assert store.metrics.counter("journal.duplicate_results").value == 2
        assert store.record("t2", value=9) is False  # but later delivery does
        assert store.metrics.counter("journal.duplicate_results").value == 3

    def test_result_store_bounded(self):
        store = ResultStore(max_entries=4)
        for i in range(10):
            store.record(f"t{i}", value=i)
        assert len(store) == 4
        assert "t9" in store and "t0" not in store

    def test_duplicate_delivery_resolves_future_exactly_once(self):
        svc = FunctionService()
        svc.make_endpoint("ep", n_executors=1)
        fid = svc.register_function(plain_double)
        fut = svc.run(fid, 4)
        assert fut.result(10) == 8
        # a replayed ResultBatch / restarted-endpoint delivery arrives late:
        fwd = svc.forwarder
        assert fwd.resolve(fut.task_id, value=999) is False
        assert fwd.resolve(fut.task_id, error=RuntimeError("late")) is False
        assert fut.result(0) == 8  # the committed result never changes
        assert svc.metrics.counter("journal.duplicate_results").value >= 2
        svc.shutdown()

    def test_resolve_completes_unresolved_future_once(self):
        fwd = Forwarder()
        env_fut = TaskFuture("t-manual")
        fwd._futures["t-manual"] = env_fut
        assert fwd.resolve("t-manual", value=42) is True
        assert env_fut.result(0) == 42
        assert fwd.resolve("t-manual", value=43) is False
        fwd.shutdown()


# ---------------------------------------------------------------------------
# Resume: tasks
# ---------------------------------------------------------------------------
class TestTaskResume:
    def _journal_task(self, j, task_id, payload, owner=None, fid=None):
        j.append("task", "submitted", task_id=task_id,
                 function_id=fid, payload=serializer.packb(payload),
                 container="default", requirements=[], max_retries=2,
                 owner=owner)

    def test_resume_reruns_only_uncommitted_tasks(self, tmp_path):
        wal = str(tmp_path / "wal")
        svc = FunctionService(journal_dir=wal)
        svc.make_endpoint("ep", n_executors=1)
        fid = svc.register_function(tracked_inc)
        done = svc.run(fid, 10)
        assert done.result(10) == 11
        # journaled-but-never-executed work, then the fabric dies:
        self._journal_task(svc.journal, "t-lost", 20, fid=fid)
        svc.journal.close()
        svc.shutdown()
        ran_before = list(EXECUTED)

        svc2 = FunctionService()
        svc2.make_endpoint("ep2", n_executors=1)
        assert svc2.register_function(tracked_inc) == fid  # stable identity
        report = svc2.resume(journal_dir=wal)
        assert set(report.futures) == {"t-lost"}  # only the uncommitted task
        assert report.futures["t-lost"].result(10) == 21
        assert EXECUTED == ran_before + [20]
        st = svc2.journal.state()
        assert st.tasks["t-lost"].terminal
        assert st.tasks[done.task_id].terminal
        assert st.duplicate_completions == 0
        svc2.shutdown()

    def test_resume_skips_owned_and_unregistered(self, tmp_path):
        wal = str(tmp_path / "wal")
        j = Journal(wal)
        self._journal_task(j, "t-owned", 1, owner="wfrun-abc", fid="fid-x")
        self._journal_task(j, "t-unknown", 2, fid="fid-missing")
        j.close()
        svc = FunctionService()
        svc.make_endpoint("ep", n_executors=1)
        report = svc.resume(journal_dir=wal)
        assert report.futures == {}  # owned work is the workflow's to re-run
        assert ("t-unknown", "function 'fid-missing' not registered") in (
            report.skipped
        )
        svc.shutdown()

    def test_resume_requires_a_journal(self):
        svc = FunctionService()
        with pytest.raises(ValueError, match="journal"):
            svc.resume()
        svc.shutdown()

    def test_terminal_ids_primed_against_replay(self, tmp_path):
        wal = str(tmp_path / "wal")
        j = Journal(wal)
        self._journal_task(j, "t-done", 1, fid="f")
        j.append("task", "completed", task_id="t-done",
                 value=serializer.packb(2))
        j.close()
        svc = FunctionService()
        svc.make_endpoint("ep", n_executors=1)
        svc.resume(journal_dir=wal)
        # a replayed late delivery for committed work dedupes, not resolves
        assert svc.forwarder.resolve("t-done", value=999) is False
        assert svc.metrics.counter("journal.duplicate_results").value == 1
        svc.shutdown()


# ---------------------------------------------------------------------------
# Resume: workflow runs
# ---------------------------------------------------------------------------
def _chain(fid, n=3):
    nodes = [WorkflowNode("n0", fid)]
    for i in range(1, n):
        nodes.append(WorkflowNode(f"n{i}", fid, deps=[f"n{i-1}"]))
    return Workflow(nodes, name="durable-chain")


class TestWorkflowResume:
    def test_run_lifecycle_journaled(self, tmp_path):
        svc = FunctionService(journal_dir=str(tmp_path / "wal"))
        svc.make_endpoint("ep", n_executors=1)
        fid = svc.register_function(tracked_inc)
        wf = _chain(fid)
        run = wf.start(svc, 0)
        assert run.wait(10) == 3
        entry = svc.journal.state().runs[run.run_id]
        assert entry.state == "SUCCEEDED"
        assert sorted(entry.done_nodes()) == ["n0", "n1", "n2"]
        svc.shutdown()

    def test_resume_reruns_only_incomplete_nodes(self, tmp_path):
        wal = str(tmp_path / "wal")
        j = Journal(wal)
        # a run killed after n0 committed: n1/n2 never finished
        j.append("run", "started", run_id="wfrun-res", workflow="durable-chain",
                 document=serializer.packb(0), nodes=["n0", "n1", "n2"])
        j.append("run", "node_completed", run_id="wfrun-res", node="n0",
                 result=serializer.packb(1))
        j.close()

        svc = FunctionService()
        svc.make_endpoint("ep", n_executors=1)
        fid_expected = svc.register_function(tracked_inc)
        wf = _chain(fid_expected)
        report = svc.resume(journal_dir=wal, workflows=[wf])
        run = report.runs["wfrun-res"]
        assert run.wait(10) == 3
        # only n1 (input 1) and n2 (input 2) executed — n0 was replayed
        assert sorted(EXECUTED) == [1, 2]
        st = svc.journal.state()
        entry = st.runs["wfrun-res"]
        assert entry.state == "SUCCEEDED" and entry.resumed == 1
        assert st.duplicate_completions == 0
        svc.shutdown()

    def test_resume_without_definition_is_skipped(self, tmp_path):
        wal = str(tmp_path / "wal")
        j = Journal(wal)
        j.append("run", "started", run_id="wfrun-orphan", workflow="nameless",
                 document=serializer.packb(0), nodes=["n0"])
        j.close()
        svc = FunctionService()
        svc.make_endpoint("ep", n_executors=1)
        report = svc.resume(journal_dir=wal)
        assert report.runs == {}
        assert any(rid == "wfrun-orphan" for rid, _ in report.skipped)
        svc.shutdown()

    def test_fully_replayed_run_finishes_without_execution(self, tmp_path):
        wal = str(tmp_path / "wal")
        j = Journal(wal)
        j.append("run", "started", run_id="wfrun-done", workflow="durable-chain",
                 document=serializer.packb(0), nodes=["n0", "n1", "n2"])
        for i, node in enumerate(("n0", "n1", "n2")):
            j.append("run", "node_completed", run_id="wfrun-done", node=node,
                     result=serializer.packb(i + 1))
        j.close()
        svc = FunctionService()
        svc.make_endpoint("ep", n_executors=1)
        fid = svc.register_function(tracked_inc)
        report = svc.resume(journal_dir=wal, workflows=[_chain(fid)])
        run = report.runs["wfrun-done"]
        assert run.wait(5) == 3
        assert EXECUTED == []  # nothing re-ran: every node was committed
        svc.shutdown()

    def test_cancelled_run_commits_terminal_record(self, tmp_path):
        svc = FunctionService(journal_dir=str(tmp_path / "wal"))
        svc.make_endpoint("ep", n_executors=1)
        fid = svc.register_function(plain_double)
        wf = Workflow([WorkflowNode("only", fid)], name="cancel-me")
        run = wf.start(svc, 1)
        run.cancel()
        entry = svc.journal.state().runs[run.run_id]
        assert entry.terminal  # a cancelled run must never resume
        svc.shutdown()


# ---------------------------------------------------------------------------
# Data fabric durability: spilled payloads across crash/restart, speculation
# ---------------------------------------------------------------------------
def spill_sum(doc):
    with _EXECUTED_LOCK:
        EXECUTED.append(doc["i"])
    import numpy as np

    return int(np.asarray(doc["pad"]).sum()) + doc["i"]


class TestDataFabricDurability:
    def test_resume_reruns_spilled_payload_after_restart(self, tmp_path):
        """A journaled task whose payload spilled into a filesystem store
        must re-run after a full crash/restart: the WAL holds only a DataRef,
        the new process holds no store registry, and the ref still resolves
        because fs:// stores re-attach by path."""
        import numpy as np

        from repro.core import FileSystemStore, reset_store_registry
        from repro.core.datastore import scan_refs, spill_payload

        wal = str(tmp_path / "wal")
        store = FileSystemStore(os.path.join(wal, "store"))
        svc = FunctionService(
            journal_dir=wal, datastore=store, spill_threshold=1024,
        )
        svc.make_endpoint("ep", n_executors=1)
        fid = svc.register_function(spill_sum)
        pad = np.ones(1024, dtype=np.int64)  # 8 KiB: spills
        done = svc.run(fid, {"i": 1, "pad": pad})
        assert done.result(10) == 1025
        # journaled-but-never-executed spilled work, then the fabric dies:
        spilled, refs = spill_payload({"i": 5, "pad": pad}, store, 1024)
        assert refs, "fixture must actually spill"
        svc.journal.append(
            "task", "submitted", task_id="t-spilled", function_id=fid,
            payload=serializer.packb(spilled), container="default",
            requirements=[], max_retries=2, owner=None,
        )
        svc.journal.close()
        svc.shutdown()
        reset_store_registry()  # a restarted process starts with no stores

        svc2 = FunctionService()
        svc2.make_endpoint("ep2", n_executors=1)
        assert svc2.register_function(spill_sum) == fid
        report = svc2.resume(journal_dir=wal)
        assert set(report.futures) == {"t-spilled"}
        assert report.futures["t-spilled"].result(10) == 1029
        assert EXECUTED == [1, 5]
        st = svc2.journal.state()
        assert st.tasks["t-spilled"].terminal
        assert st.duplicate_completions == 0
        svc2.shutdown()

    def test_resume_fails_cleanly_when_blobs_are_gone(self, tmp_path):
        """Losing the blob directory must surface as a task failure, not a
        hang or a duplicate commitment."""
        import shutil

        import numpy as np

        from repro.core import FileSystemStore, reset_store_registry
        from repro.core.datastore import spill_payload

        wal = str(tmp_path / "wal")
        blob_dir = os.path.join(wal, "store")
        store = FileSystemStore(blob_dir)
        svc = FunctionService(journal_dir=wal)
        svc.make_endpoint("ep", n_executors=1)
        fid = svc.register_function(spill_sum)
        pad = np.ones(512, dtype=np.int64)
        spilled, _ = spill_payload({"i": 0, "pad": pad}, store, 1024)
        svc.journal.append(
            "task", "submitted", task_id="t-orphan", function_id=fid,
            payload=serializer.packb(spilled), container="default",
            requirements=[], max_retries=0, owner=None,
        )
        svc.journal.close()
        svc.shutdown()
        reset_store_registry()
        shutil.rmtree(blob_dir)  # the data is gone for good

        svc2 = FunctionService()
        svc2.make_endpoint("ep2", n_executors=1)
        svc2.register_function(spill_sum)
        report = svc2.resume(journal_dir=wal)
        fut = report.futures["t-orphan"]
        with pytest.raises(Exception):
            fut.result(10)
        assert svc2.journal.state().duplicate_completions == 0
        svc2.shutdown()

    def test_speculation_survives_restart_without_double_commit(self, tmp_path):
        """Chaos-lite: a speculating fabric over spilled payloads is killed
        mid-stream and resumed; every task commits exactly once even though
        backup copies of stragglers were in flight."""
        import time as _time

        import numpy as np

        from repro.core import FileSystemStore, reset_store_registry

        wal = str(tmp_path / "wal")
        pad = np.ones(1024, dtype=np.int64)

        def build(with_journal):
            fwd = Forwarder(
                policy="eta_aware", speculation=True,
                speculation_eta_factor=0.5, speculation_min_age_s=0.01,
                watchdog_interval_s=0.01,
            )
            svc = FunctionService(
                forwarder=fwd,
                journal_dir=wal if with_journal else None,
                datastore=FileSystemStore(os.path.join(wal, "store")),
                spill_threshold=1024,
            )
            svc.make_endpoint("sp0", n_executors=1, workers_per_executor=2)
            svc.make_endpoint("sp1", n_executors=1, workers_per_executor=2)
            return svc, svc.register_function(spill_sum)

        svc, fid = build(with_journal=True)
        futs = svc.batch_run(
            fid, [{"i": i, "pad": pad} for i in range(8)], max_retries=3,
        )
        # kill the fabric while some tasks (and possibly backups) fly
        _time.sleep(0.05)
        svc.journal.close()
        svc.shutdown()
        reset_store_registry()

        svc2, fid2 = build(with_journal=False)
        report = svc2.resume(journal_dir=wal)
        for fut in report.futures.values():
            assert fut.result(30) >= 1024
        st = svc2.journal.state()
        assert st.duplicate_completions == 0
        assert not any("#eta" in tid for tid in st.tasks)
        done = [t for t, e in st.tasks.items() if e.terminal]
        assert len(done) == 8
        _ = futs  # pre-crash futures die with the old fabric
        svc2.shutdown()


# ---------------------------------------------------------------------------
# Full fabric crash/restart sweep (the chaos tier, in-suite)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_high_fault_rate_chaos_sweep(tmp_path):
    """The benchmark's property at an aggressive fault rate: every round
    completes, exactly-once holds (journal-verified), latency stays
    bounded."""
    import random

    from benchmarks.bench_chaos import _round

    rng = random.Random(99)
    for i in range(3):
        lats, restarts, _dups = _round(0.5, rng, str(tmp_path), 24, 5)
        assert len(lats) == 24
        assert restarts == 1
