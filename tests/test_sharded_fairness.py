"""Sharded forwarder + multi-tenant fairness (million-task scale tier).

Covers the scale push: task→shard hash partitioning, the ShardedForwarder's
Forwarder-shaped surface (routing, failover, journal resume), per-tenant
quota admission with retry_after, deficit-round-robin fair share, the
narrowed _on_done lock (completions from many threads while submitting), and
payload sharing on clone/speculation.
"""
import threading
import time

import pytest

from repro.core import (
    AdmissionError,
    DeficitRoundRobin,
    FairnessPolicy,
    Forwarder,
    FunctionService,
    ShardedForwarder,
    TaskEnvelope,
    TaskFuture,
    TenantLedger,
    TokenAuthority,
    shard_of,
)
from repro.core.auth import SCOPE_INVOKE


class FakeEndpoint:
    def __init__(self, eid, capacity=4, alive=True):
        self.endpoint_id = eid
        self._capacity = capacity
        self._alive = alive
        self.submitted = []

    def is_alive(self, max_heartbeat_age_s=None):
        return self._alive

    def capacity(self):
        return self._capacity

    def has_warm(self, key):
        return False

    def submit(self, env, future):
        self.submitted.append(env)

    def shutdown(self):
        pass


def _env(i=0, fn="f", tenant=None):
    return TaskEnvelope(task_id=f"t{i}", function_id=fn, payload=b"", tenant=tenant)


def _pairs(n, tenant=None, prefix="t"):
    out = []
    for i in range(n):
        env = TaskEnvelope(
            task_id=f"{prefix}{i}", function_id="f", payload=b"", tenant=tenant
        )
        out.append((env, TaskFuture(env.task_id)))
    return out


@pytest.fixture()
def sharded_factory():
    created = []

    def make(n_shards=3, endpoints=(), **kwargs):
        kwargs.setdefault("watchdog_interval_s", 5.0)
        sf = ShardedForwarder(n_shards=n_shards, **kwargs)
        for ep in endpoints:
            sf.register(ep)
        created.append(sf)
        return sf

    yield make
    for sf in created:
        sf.shutdown()


# ------------------------------------------------------------- partitioning
def test_shard_assignment_is_stable():
    ids = [f"task-{i}-deadbeef" for i in range(200)]
    first = [shard_of(t, 8) for t in ids]
    assert first == [shard_of(t, 8) for t in ids]  # same id → same shard
    assert all(0 <= s < 8 for s in first)
    assert len(set(first)) == 8  # 200 ids cover all 8 shards


def test_sharded_forwarder_owns_each_task_in_exactly_one_shard(sharded_factory):
    sf = sharded_factory(n_shards=4, endpoints=[FakeEndpoint("a", capacity=64)])
    pairs = _pairs(64)
    chosen = sf.submit_many(pairs)
    assert chosen == ["a"] * 64
    for env, _ in pairs:
        owner = sf.shard_index(env.task_id)
        for i, fwd in enumerate(sf.shards):
            assert (env.task_id in fwd._futures) == (i == owner)
    for _, fut in pairs:
        fut.set_result(0)
    stats = sf.stats()
    assert stats["endpoints"]["a"]["routed"] == 64
    assert stats["endpoints"]["a"]["outstanding"] == 0
    assert sum(s["endpoints"]["a"]["completed"] for s in stats["shards"]) == 64


def test_single_forwarder_is_the_degenerate_case(sharded_factory):
    """One shard behaves exactly like a bare Forwarder for routing results."""
    sf = sharded_factory(n_shards=1, endpoints=[FakeEndpoint("a"), FakeEndpoint("b")])
    picks = sf.submit_many(_pairs(4))
    assert sorted(picks) == ["a", "a", "b", "b"]  # least_outstanding spread


def test_cross_shard_failover(sharded_factory):
    a = FakeEndpoint("a", capacity=64)
    b = FakeEndpoint("b", capacity=64)
    sf = sharded_factory(n_shards=3, endpoints=[a, b])
    pairs = _pairs(48)
    sf.submit_many(pairs)
    a._alive = False
    dead = sf.check_endpoints()
    assert dead == ["a"]
    # every task routed to the dead endpoint — across EVERY shard — moved to b
    for env, fut in pairs:
        assert fut.endpoint_id == "b"
        owner = sf.shard_for(env.task_id)
        assert owner._task_endpoint[env.task_id] == "b"
    assert sf.failovers > 0
    shards_that_failed_over = [f for f in sf.shards if f.failovers > 0]
    assert len(shards_that_failed_over) >= 2  # not a single-shard accident
    for _, fut in pairs:
        fut.set_result(0)
    assert sf.stats()["endpoints"]["b"]["outstanding"] == 0


def test_sharded_resume_primes_every_shards_result_store(tmp_path):
    wal = str(tmp_path / "wal")
    svc = FunctionService(journal_dir=wal, n_shards=3)
    svc.make_endpoint("ep", n_executors=2)
    fid = svc.register_function(lambda x: x + 1, name="inc3")
    futs = [svc.run(fid, i) for i in range(30)]
    done_ids = [f.task_id for f in futs]
    assert [f.result(10) for f in futs] == [i + 1 for i in range(30)]
    svc.journal.close()
    svc.shutdown()

    svc2 = FunctionService(n_shards=3)
    svc2.make_endpoint("ep2", n_executors=2)
    svc2.register_function(lambda x: x + 1, name="inc3")
    report = svc2.resume(journal_dir=wal)
    assert report.futures == {}  # everything was committed: nothing re-runs
    sf = svc2.forwarder
    assert isinstance(sf, ShardedForwarder)
    for tid in done_ids:
        owner = sf.shard_index(tid)
        for i, fwd in enumerate(sf.shards):
            assert (tid in fwd.results) == (i == owner)
    # the 30 ids land in >1 shard, so priming genuinely fanned out
    assert sum(1 for f in sf.shards if len(f.results)) >= 2
    assert svc2.journal.state().duplicate_completions == 0
    svc2.shutdown()


# ---------------------------------------------------------------- fairness
def test_drr_fair_share_math():
    policy = FairnessPolicy(quantum=4, weights={"heavy": 3.0, "light": 1.0})
    drr = DeficitRoundRobin(policy)
    for i in range(300):
        drr.enqueue("heavy", ("heavy", i))
    for i in range(100):
        drr.enqueue("light", ("light", i))
    got = drr.drain(160)
    assert len(got) == 160
    heavy = sum(1 for t, _ in got if t == "heavy")
    light = len(got) - heavy
    # weight 3:1 → expect ~120:40; allow quantum-granularity slack
    assert 2.0 <= heavy / light <= 4.0
    assert drr.pending() == 400 - 160


def test_drr_equal_weights_interleave():
    drr = DeficitRoundRobin(FairnessPolicy(quantum=1))
    for i in range(10):
        drr.enqueue("a", ("a", i))
        drr.enqueue("b", ("b", i))
    got = drr.drain(10)
    assert sum(1 for t, _ in got if t == "a") == 5
    assert sum(1 for t, _ in got if t == "b") == 5


def test_drr_light_tenant_jumps_greedy_backlog():
    """A late-arriving light tenant drains ahead of a greedy backlog rather
    than behind it (the anti-starvation property FIFO lacks)."""
    drr = DeficitRoundRobin(FairnessPolicy(quantum=2))
    for i in range(1000):
        drr.enqueue("greedy", ("greedy", i))
    drr.enqueue("light", ("light", 0))
    got = drr.drain(8)
    assert ("light", 0) in got


def test_admission_rejects_beyond_quota_with_retry_after():
    fwd = Forwarder(
        fairness=FairnessPolicy(default_quota=4), watchdog_interval_s=5.0
    )
    try:
        fwd.register(FakeEndpoint("a", capacity=100))
        pairs = _pairs(10, tenant="alice")
        fwd.submit_many(pairs)
        rejected = [f for _, f in pairs if f.done()]
        assert len(rejected) == 6  # 4 admitted, 6 over quota
        for fut in rejected:
            exc = fut.exception(0)
            assert isinstance(exc, AdmissionError)
            assert exc.tenant == "alice"
            assert exc.quota == 4
            assert exc.retry_after > 0
        # completing the in-flight tasks frees quota slots
        deadline = time.monotonic() + 2.0
        while fwd.ledger.outstanding("alice") < 4:
            assert time.monotonic() < deadline
            time.sleep(0.005)
        for env, fut in pairs:
            if not fut.done():
                fut.set_result(0)
        deadline = time.monotonic() + 2.0
        while fwd.ledger.outstanding("alice"):
            assert time.monotonic() < deadline
            time.sleep(0.005)
        again = _pairs(1, tenant="alice", prefix="again")
        fwd.submit_many(again)
        time.sleep(0.05)
        assert not again[0][1].done()
    finally:
        fwd.shutdown()


def test_quota_slot_freed_on_any_terminal_state():
    fwd = Forwarder(
        fairness=FairnessPolicy(default_quota=2), watchdog_interval_s=5.0
    )
    try:
        fwd.register(FakeEndpoint("a", capacity=10))
        pairs = _pairs(2, tenant="bob")
        fwd.submit_many(pairs)
        pairs[0][1].set_exception(RuntimeError("boom"))
        pairs[1][1].cancel()
        deadline = time.monotonic() + 2.0
        while fwd.ledger.outstanding("bob"):
            assert time.monotonic() < deadline
            time.sleep(0.005)
    finally:
        fwd.shutdown()


def test_fairness_quota_from_auth_tenant_profiles():
    authority = TokenAuthority()
    authority.set_tenant_profile("carol", quota=2, weight=5.0)
    policy = FairnessPolicy(default_quota=100).bind_profiles(authority)
    assert policy.quota_of("carol") == 2
    assert policy.weight_of("carol") == 5.0
    assert policy.quota_of("stranger") == 100
    # explicit policy entries still win over profiles
    policy.quotas["carol"] = 7
    assert policy.quota_of("carol") == 7


def test_service_stamps_tenant_and_enforces_profile_quota():
    authority = TokenAuthority()
    authority.set_tenant_profile("dave", quota=3)
    svc = FunctionService(authority=authority, fairness=FairnessPolicy())
    ep = FakeEndpoint("a", capacity=100)
    ep_token = authority.issue("ops", scopes=(SCOPE_INVOKE, "register_endpoint"))
    svc.register_endpoint(ep, token=ep_token)
    token = authority.issue("dave", scopes=(SCOPE_INVOKE,))
    fid = svc.register_function(
        lambda x: x, name="idly", public=True,
        token=authority.issue("owner", scopes=("register_function",)),
    )
    futs = [svc.run(fid, i, token=token) for i in range(5)]
    time.sleep(0.1)
    rejected = [f for f in futs if f.done() and isinstance(f.exception(0), AdmissionError)]
    assert len(rejected) == 2
    assert all(r.exception(0).tenant == "dave" for r in rejected)
    # the admitted envelopes carried the verified identity
    admitted = [env for env in ep.submitted]
    assert admitted and all(env.tenant == "dave" for env in admitted)
    svc.shutdown()


def test_fair_drain_is_capacity_bounded():
    """The pump drains only into spare endpoint capacity; the excess stays in
    tenant queues instead of piling onto the endpoint."""
    fwd = Forwarder(fairness=FairnessPolicy(), watchdog_interval_s=5.0)
    try:
        ep = FakeEndpoint("a", capacity=8)
        fwd.register(ep)
        pairs = _pairs(50, tenant="erin")
        fwd.submit_many(pairs)
        deadline = time.monotonic() + 2.0
        while len(ep.submitted) < 8:
            assert time.monotonic() < deadline
            time.sleep(0.005)
        time.sleep(0.05)
        assert len(ep.submitted) == 8  # exactly the capacity, no more
        assert fwd.stats()["fair_pending"] == 42
        # completions free capacity → the pump keeps draining to done
        for _ in range(20):
            for env in list(ep.submitted):
                fut = fwd._futures.get(env.task_id)
                if fut is not None and not fut.done():
                    fut.set_result(0)
            if all(f.done() for _, f in pairs):
                break
            time.sleep(0.02)
        assert all(f.done() for _, f in pairs)
    finally:
        fwd.shutdown()


def test_greedy_tenant_cannot_lock_out_light_tenant():
    """With a quota on the greedy tenant, a light tenant arriving behind a
    large backlog still gets routed promptly (DRR + admission)."""
    fwd = Forwarder(
        fairness=FairnessPolicy(quotas={"greedy": 8}),
        watchdog_interval_s=5.0,
    )
    try:
        ep = FakeEndpoint("a", capacity=16)
        fwd.register(ep)
        greedy = _pairs(8, tenant="greedy", prefix="g")
        fwd.submit_many(greedy)  # fills its quota; rest would be rejected
        light = _pairs(2, tenant="light", prefix="l")
        fwd.submit_many(light)
        deadline = time.monotonic() + 2.0
        while sum(1 for e in ep.submitted if e.tenant == "light") < 2:
            assert time.monotonic() < deadline
            time.sleep(0.005)
        for _, f in greedy + light:
            if not f.done():
                f.set_result(0)
    finally:
        fwd.shutdown()


def test_sharded_fairness_shares_one_ledger(sharded_factory):
    sf = sharded_factory(
        n_shards=4,
        endpoints=[FakeEndpoint("a", capacity=100)],
        fairness=FairnessPolicy(default_quota=10),
    )
    pairs = _pairs(25, tenant="frank")
    sf.submit_many(pairs)
    rejected = [f for _, f in pairs if f.done()]
    # the quota caps fabric-wide outstanding across ALL shards, not 10/shard
    assert len(rejected) == 15
    assert all(isinstance(f.exception(0), AdmissionError) for f in rejected)
    assert sf.ledger.outstanding("frank") == 10
    for _, f in pairs:
        if not f.done():
            f.set_result(0)


# ------------------------------------------- _on_done lock-scope regression
def test_completions_from_many_threads_while_submitting():
    """Narrowed _on_done lock: resolution runs outside the global lock, so
    many completer threads racing many submitter threads neither deadlock
    nor corrupt the routing maps."""
    fwd = Forwarder(watchdog_interval_s=5.0)
    try:
        eps = [FakeEndpoint(f"e{i}", capacity=10_000) for i in range(4)]
        for ep in eps:
            fwd.register(ep)
        n_threads, per_thread = 8, 200
        all_futs = []
        futs_lock = threading.Lock()
        stop = threading.Event()

        def submitter(k):
            for j in range(per_thread):
                env = TaskEnvelope(
                    task_id=f"s{k}-{j}", function_id="f", payload=b""
                )
                fut = TaskFuture(env.task_id)
                fwd.submit(env, fut)
                with futs_lock:
                    all_futs.append(fut)

        def completer():
            while not stop.is_set():
                with futs_lock:
                    pending = [f for f in all_futs if not f.done()]
                for f in pending:
                    f.set_result(0)
                time.sleep(0.001)

        submitters = [
            threading.Thread(target=submitter, args=(k,)) for k in range(n_threads)
        ]
        completers = [threading.Thread(target=completer) for _ in range(4)]
        for t in completers:
            t.start()
        for t in submitters:
            t.start()
        for t in submitters:
            t.join(timeout=30)
            assert not t.is_alive(), "submitter deadlocked"
        deadline = time.monotonic() + 10
        while True:
            with futs_lock:
                if len(all_futs) == n_threads * per_thread and all(
                    f.done() for f in all_futs
                ):
                    break
            assert time.monotonic() < deadline, "completions stalled"
            time.sleep(0.01)
        stop.set()
        for t in completers:
            t.join(timeout=5)
        stats = fwd.stats()
        assert sum(e["outstanding"] for e in stats["endpoints"].values()) == 0
        assert sum(e["completed"] for e in stats["endpoints"].values()) == (
            n_threads * per_thread
        )
        assert not fwd._futures and not fwd._task_endpoint
    finally:
        fwd.shutdown()


# ------------------------------------------------- clone/speculation payload
def test_clones_share_payload_bytes():
    env = TaskEnvelope(
        task_id="t", function_id="f", payload=b"x" * 4096, tenant="alice"
    )
    retry = env.clone_for_retry()
    assert retry.payload is env.payload  # identity, not equality
    assert retry.retries == env.retries + 1
    assert retry.tenant == "alice"
    backup = env.clone_speculative("#eta")
    assert backup.payload is env.payload
    assert backup.task_id == "t#eta"
    assert backup.speculative_of == "t"
    assert backup.max_retries == 0
    assert backup.tenant == "alice"
    assert backup.timestamps is env.timestamps  # one logical task, one trail


def test_speculative_backup_aliases_payload_through_forwarder():
    fwd = Forwarder(
        policy="eta_aware", speculation=True, speculation_min_age_s=0.0,
        speculation_eta_factor=0.0, watchdog_interval_s=5.0,
    )
    try:
        a, b = FakeEndpoint("a", capacity=4), FakeEndpoint("b", capacity=4)
        fwd.register(a)
        fwd.register(b)
        env = TaskEnvelope(task_id="slow", function_id="f", payload=b"p" * 1024)
        fut = TaskFuture("slow")
        fwd.submit(env, fut)
        time.sleep(0.02)
        assert fwd.check_speculation() == 1
        dup = next(
            e for ep in (a, b) for e in ep.submitted if e.task_id == "slow#eta"
        )
        assert dup.payload is env.payload
        assert dup.speculative_of == "slow"
        # first result wins, loser dedupes: unchanged speculation behavior
        assert fut.set_result(1)
        assert not fut.set_result(2)
        assert fwd.results.get("slow") == (1, None)
    finally:
        fwd.shutdown()
