"""Automation tier: DAG Workflow engine, EventBus triggers, and the linear
Flow shim (plus the two seed-flow regressions: cancel detaching the in-flight
future, and iterative — non-recursive — chain advancement)."""
import threading
import time

import pytest

from repro.core import (
    ActionStep,
    DataArrivalEvent,
    EventBus,
    Flow,
    FunctionService,
    TimerSource,
    Trigger,
    Workflow,
    WorkflowNode,
    serializer,
)


@pytest.fixture()
def svc():
    service = FunctionService()
    service.make_endpoint("wf-ep", n_executors=1, workers_per_executor=4)
    yield service
    service.shutdown()


# ------------------------------------------------------------ graph validation
def test_workflow_validates_graph():
    with pytest.raises(ValueError, match="duplicate"):
        Workflow([WorkflowNode("a", "f"), WorkflowNode("a", "f")])
    with pytest.raises(ValueError, match="unknown"):
        Workflow([WorkflowNode("a", "f", deps=["ghost"])])
    with pytest.raises(ValueError, match="cycle"):
        Workflow([
            WorkflowNode("a", "f", deps=["b"]),
            WorkflowNode("b", "f", deps=["a"]),
        ])
    with pytest.raises(ValueError, match="on_error"):
        WorkflowNode("a", "f", on_error="explode")


def test_topological_order_respects_deps():
    wf = Workflow([
        WorkflowNode("join", "f", deps=["a", "b"]),
        WorkflowNode("b", "f", deps=["src"]),
        WorkflowNode("a", "f", deps=["src"]),
        WorkflowNode("src", "f"),
    ])
    order = wf.topological_order()
    assert order.index("src") < order.index("a") < order.index("join")
    assert order.index("src") < order.index("b") < order.index("join")
    assert wf.sinks == ["join"]


# ------------------------------------------------------------ DAG execution
def test_dag_ordering_and_merged_results(svc):
    seen = []
    lock = threading.Lock()

    def record(tag):
        def fn(doc):
            with lock:
                seen.append(tag)
            return dict(doc, tag=tag)
        return fn

    fa = svc.register_function(record("a"))
    fb = svc.register_function(record("b"))
    fc = svc.register_function(record("c"))
    wf = Workflow([
        WorkflowNode("c", fc, deps=["b"]),
        WorkflowNode("b", fb, deps=["a"]),
        WorkflowNode("a", fa),
    ])
    run = wf.start(svc, {"v": 1})
    out = run.wait(30)
    assert seen == ["a", "b", "c"]          # chain executes in dependency order
    assert out == {"v": 1, "tag": "c"}       # single sink -> bare result
    assert run.state == "SUCCEEDED"
    assert [h["node"] for h in run.history] == ["a", "b", "c"]


def test_diamond_fanout_fanin_results_and_sibling_batching(svc):
    def source(doc):
        return {"v": doc["v"]}

    def double(x):
        return {"v": x["v"] * 2}

    def plus_one(x):
        return {"v": x["v"] + 1}

    def join(upstream):
        return {"sum": upstream["left"]["v"] + upstream["right"]["v"]}

    wf = Workflow([
        WorkflowNode("src", svc.register_function(source)),
        WorkflowNode("left", svc.register_function(double), deps=["src"]),
        WorkflowNode("right", svc.register_function(plus_one), deps=["src"]),
        WorkflowNode("join", svc.register_function(join), deps=["left", "right"]),
    ], name="diamond")
    run = wf.start(svc, {"v": 10})
    assert run.wait(30) == {"sum": 31}       # (10*2) + (10+1)
    # fan-in saw both branches; node states all terminal-success
    assert all(s == "SUCCEEDED" for s in run.node_states.values())

    # the sibling branches travelled as ONE TaskBatch frame: 3 deliveries
    # total (src), (left+right), (join) — not 4
    stats = svc.forwarder.stats()
    assert stats["batches_delivered"] == 3
    assert stats["tasks_delivered"] == 4
    hist = svc.metrics.snapshot()["histograms"]["forwarder.batch_size"]
    assert hist["count"] == 3 and hist["sum"] == 4.0


def test_fanout_results_are_per_branch(svc):
    def source(doc):
        return doc["base"]

    def scale(k):
        def fn(x):
            return x * k
        return fn

    fid_src = svc.register_function(source)
    nodes = [WorkflowNode("src", fid_src)]
    for k in (2, 3, 5):
        nodes.append(WorkflowNode(
            f"x{k}", svc.register_function(scale(k)), deps=["src"]
        ))
    wf = Workflow(nodes)
    run = wf.start(svc, {"base": 7})
    out = run.wait(30)                        # three sinks -> dict of results
    assert out == {"x2": 14, "x3": 21, "x5": 35}


def test_workflow_warm_affinity_hints_children_to_parent_endpoint(svc):
    ep2 = svc.make_endpoint("wf-ep2", n_executors=1, workers_per_executor=4)

    def step(doc):
        return doc

    fid = svc.register_function(step)
    wf = Workflow([
        WorkflowNode("parent", fid, endpoint_id=ep2.endpoint_id),
        WorkflowNode("child", fid, deps=["parent"]),
    ])
    run = wf.start(svc, {"v": 1})
    run.wait(30)
    # the unpinned child followed its parent's warm endpoint
    assert run.node_endpoint["parent"] == ep2.endpoint_id
    assert run.node_endpoint["child"] == ep2.endpoint_id
    hits = svc.metrics.snapshot()["counters"].get("forwarder.affinity_hits", 0)
    assert hits >= 1


# ------------------------------------------------------------ retry / on_error
def test_node_retry_policy_resubmits_until_success(svc):
    calls = {"n": 0}

    def flaky(doc):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError(f"transient #{calls['n']}")
        return {"ok": calls["n"]}

    fid = svc.register_function(flaky)
    # max_retries=0 disables transport-level retry so the workflow's own
    # attempt accounting is what drives re-submission
    wf = Workflow([WorkflowNode("flaky", fid, max_attempts=3, max_retries=0)])
    run = wf.start(svc, {})
    assert run.wait(30) == {"ok": 3}
    assert run.attempts["flaky"] == 3
    snap = svc.metrics.snapshot()["counters"]
    assert snap.get("workflow.node_retries", 0) == 2
    retry_entries = [h for h in run.history if h["state"] == "RETRYING"]
    assert len(retry_entries) == 2


def test_node_retries_exhausted_fails_run(svc):
    def always_fails(doc):
        raise ValueError("permanently broken")

    fid = svc.register_function(always_fails)
    wf = Workflow([
        WorkflowNode("bad", fid, max_attempts=2, max_retries=0),
        WorkflowNode("after", fid, deps=["bad"]),
    ])
    run = wf.start(svc, {})
    with pytest.raises(RuntimeError, match="failed"):
        run.wait(30)
    assert run.state == "FAILED"
    assert run.node_states["bad"] == "FAILED"
    assert run.node_states["after"] == "PENDING"   # never launched
    assert "bad" in run.error


def test_on_error_skip_records_fallback_and_continues(svc):
    def broken(doc):
        raise RuntimeError("sensor offline")

    def downstream(upstream):
        return {"got": upstream}

    f_bad = svc.register_function(broken)
    f_down = svc.register_function(downstream)
    wf = Workflow([
        WorkflowNode("maybe", f_bad, max_retries=0, on_error="skip",
                     fallback={"v": -1}),
        WorkflowNode("down", f_down, deps=["maybe"]),
    ])
    run = wf.start(svc, {})
    assert run.wait(30) == {"got": {"v": -1}}
    assert run.node_states["maybe"] == "SKIPPED"
    assert run.node_states["down"] == "SUCCEEDED"


def test_mid_dag_submission_error_fails_run_not_fabric(svc):
    """A submission error while launching a child (unknown function id) must
    fail the run with the real error — not escape through the parent's
    completion callback into the endpoint manager thread and hang the run."""
    fid = svc.register_function(lambda doc: doc)
    wf = Workflow([
        WorkflowNode("a", fid),
        WorkflowNode("b", "no-such-function", deps=["a"]),
    ])
    run = wf.start(svc, {"v": 1})
    with pytest.raises(RuntimeError, match="no-such-function"):
        run.wait(10)
    assert run.state == "FAILED"
    assert run.node_states["b"] == "FAILED"
    # the fabric survived: the endpoint still executes ordinary tasks
    assert svc.run(fid, {"ok": 1}).result(10) == {"ok": 1}


def test_prepare_failure_honors_on_error(svc):
    def fine(doc):
        return doc

    fid = svc.register_function(fine)

    def bad_prepare(doc, upstream):
        raise KeyError("missing field")

    wf = Workflow([WorkflowNode("p", fid, prepare=bad_prepare)])
    run = wf.start(svc, {})
    with pytest.raises(RuntimeError):
        run.wait(30)
    assert run.state == "FAILED"


# ------------------------------------------------------------ cancel
def test_cancel_mid_dag_detaches_inflight_and_stops_progress(svc):
    release = threading.Event()
    downstream_ran = threading.Event()

    def slow(doc):
        release.wait(10)
        return doc

    def after(doc):
        downstream_ran.set()
        return doc

    f_slow = svc.register_function(slow)
    f_after = svc.register_function(after)
    wf = Workflow([
        WorkflowNode("slow", f_slow),
        WorkflowNode("after", f_after, deps=["slow"]),
    ])
    run = wf.start(svc, {"v": 1})
    time.sleep(0.05)                    # let `slow` reach a worker
    inflight = [f for f, _ in run.inflight.values()]
    assert inflight, "slow node should be in flight"
    run.cancel()
    assert run.state == "CANCELLED"
    assert not run.inflight

    release.set()                       # the in-flight task completes late...
    assert inflight[0].result(10) == {"v": 1}
    time.sleep(0.1)
    assert not downstream_ran.is_set()  # ...but launches nothing further
    assert run.node_states["after"] == "CANCELLED"
    with pytest.raises(RuntimeError, match="cancelled"):
        run.wait(1)


def test_flow_cancel_detaches_current_future(svc):
    """Seed regression: Flow.cancel() left run.current attached, so the
    in-flight future's completion could still drive the flow."""
    release = threading.Event()
    second_ran = threading.Event()

    def slow(doc):
        release.wait(10)
        return doc

    def second(doc):
        second_ran.set()
        return doc

    f1 = svc.register_function(slow)
    f2 = svc.register_function(second)
    flow = Flow([ActionStep(f1, name="slow"), ActionStep(f2, name="second")])
    run = flow.start(svc, {"v": 1})
    time.sleep(0.05)
    current = run.current
    assert current is not None
    Flow.cancel(run)
    assert run.state == "CANCELLED"
    assert run.current is None          # detached, not merely flagged

    release.set()
    current.result(10)                  # the task itself still finishes
    time.sleep(0.1)
    assert not second_ran.is_set()      # no further step launched
    assert run.step_index == 0


# ------------------------------------------------------------ triggers
def test_trigger_fires_workflow_run_per_matching_event(svc):
    def analyze(doc):
        return {"source": doc["source"], "n": len(doc["item"])}

    fid = svc.register_function(analyze)
    wf = Workflow([WorkflowNode("analyze", fid)])
    bus = EventBus()
    trig = bus.attach(Trigger(
        wf, svc, name="on-data",
        predicate=lambda e: e.source == "detector",
    ))
    # non-matching source: predicate filters it out
    bus.publish(DataArrivalEvent("other-site", item=[1]))
    assert trig.runs == []
    # matching events: one run each
    bus.publish(DataArrivalEvent("detector", item=[1, 2, 3]))
    bus.publish(DataArrivalEvent("detector", item=[4, 5]))
    assert len(trig.runs) == 2
    outs = [r.wait(30) for r in trig.runs]
    assert outs == [{"source": "detector", "n": 3}, {"source": "detector", "n": 2}]
    counters = svc.metrics.snapshot()["counters"]
    assert counters["trigger.fired{trigger=on-data}"] == 2
    assert counters["workflow.runs{state=succeeded}"] >= 2


def test_timer_source_fires_trigger(svc):
    def tick_fn(doc):
        return {"tick": doc["tick"]}

    fid = svc.register_function(tick_fn)
    wf = Workflow([WorkflowNode("tick", fid)])
    bus = EventBus()
    trig = bus.attach(Trigger(wf, svc, topic="timer", name="cron"))
    timer = TimerSource(bus, period_s=0.02, max_ticks=3).start()
    deadline = time.monotonic() + 5.0
    while len(trig.runs) < 3 and time.monotonic() < deadline:
        time.sleep(0.01)
    timer.stop()
    assert len(trig.runs) == 3
    assert [r.wait(30)["tick"] for r in trig.runs] == [1, 2, 3]


def test_trigger_once_disarms_after_first_firing(svc):
    fid = svc.register_function(lambda doc: doc)
    wf = Workflow([WorkflowNode("only", fid)])
    bus = EventBus()
    trig = bus.attach(Trigger(wf, svc, name="one-shot", once=True))
    bus.publish(DataArrivalEvent("s", item=1))
    bus.publish(DataArrivalEvent("s", item=2))
    assert len(trig.runs) == 1
    assert trig.fired == 1


def test_trigger_prunes_completed_runs_beyond_keep_runs(svc):
    fid = svc.register_function(lambda doc: doc)
    wf = Workflow([WorkflowNode("n", fid)])
    bus = EventBus()
    trig = bus.attach(Trigger(wf, svc, name="busy", keep_runs=3))
    for i in range(8):
        bus.publish(DataArrivalEvent("s", item=i))
        trig.runs[-1].wait(30)      # completed runs beyond the cap get pruned
    assert trig.fired == 8
    assert len(trig.runs) == 3
    assert [r.output()["item"] for r in trig.runs] == [5, 6, 7]


def test_eventbus_handler_errors_are_observable(svc):
    bus = EventBus(metrics=svc.metrics)

    def bad_handler(event):
        raise AttributeError("rule bug")

    seen = []
    bus.subscribe("data.arrival", bad_handler)
    bus.subscribe("data.arrival", seen.append)
    n = bus.publish(DataArrivalEvent("s", item=1))
    assert n == 2
    assert len(seen) == 1               # the bad rule didn't mute the good one
    assert bus.errors == 1
    assert isinstance(bus.last_error, AttributeError)
    counters = svc.metrics.snapshot()["counters"]
    assert counters["eventbus.handler_errors"] == 1


def test_start_raises_synchronously_on_bad_submission(svc):
    """Seed parity: Flow.start()/Workflow.start() surfaced unknown-function
    and auth errors in the caller's frame; a caller that never waits must
    still see them."""
    with pytest.raises(KeyError, match="ghost-function"):
        Workflow([WorkflowNode("a", "ghost-function")]).start(svc, {})
    with pytest.raises(KeyError, match="ghost-function"):
        Flow([ActionStep("ghost-function")]).start(svc, {})


# ------------------------------------------------------------ Flow shim parity
def test_flow_shim_parity_with_seed_semantics(svc):
    """The linear Flow surface: prepare/merge thread one document through the
    chain exactly as the seed implementation did."""
    def extract(doc):
        return {"values": [v * 1.0 for v in doc["raw"]]}

    def reduce_step(doc):
        return {"mean": sum(doc["values"]) / len(doc["values"])}

    f1 = svc.register_function(extract)
    f2 = svc.register_function(reduce_step)
    flow = Flow([
        ActionStep(f1, name="extract"),
        ActionStep(f2, name="reduce",
                   merge=lambda doc, result: dict(doc, **result)),
    ])
    run = flow.start(svc, {"raw": list(range(10))})
    result = Flow.wait(run, timeout=30)
    assert result["mean"] == 4.5
    assert result["values"] == [float(v) for v in range(10)]  # merge kept doc
    assert run.state == "SUCCEEDED"
    assert run.step_index == 2
    assert len(run.history) == 2
    assert [h["step"] for h in run.history] == ["extract", "reduce"]
    status = Flow.status(run)
    assert status["state"] == "SUCCEEDED" and status["step"] == 2


def test_flow_failure_surfaces_like_seed(svc):
    def boom(doc):
        raise ValueError("bad document")

    fid = svc.register_function(boom)
    flow = Flow([ActionStep(fid, name="boom")])
    run = flow.start(svc, {"v": 1})
    with pytest.raises(RuntimeError, match="flow failed"):
        Flow.wait(run, timeout=30)
    assert run.state == "FAILED"
    assert "error" in run.history[-1]


def test_flow_deep_chain_advances_iteratively(svc):
    """Seed regression: Flow._advance recursed through done-callbacks, so a
    chain of synchronously-completing (memoized) steps grew the stack by a
    frame per step and a 1000-step chain overflowed. Pre-seeding the memo
    cache makes every completion synchronous, driving the whole chain on the
    caller's stack — it must advance in a flat loop."""
    n_steps = 1000

    def incr(doc):
        return {"v": doc["v"] + 1}

    fid = svc.register_function(incr, name="incr")
    for i in range(n_steps):  # every step is a memo hit: no endpoint round-trip
        svc.memo.put(fid, serializer.payload_hash({"v": i}), {"v": i + 1})

    flow = Flow([ActionStep(fid, memoize=True, name=f"s{i}")
                 for i in range(n_steps)])
    run = flow.start(svc, {"v": 0})
    assert Flow.wait(run, timeout=30) == {"v": n_steps}
    assert run.step_index == n_steps
    assert svc.metrics.snapshot()["counters"]["service.memo_hits"] == n_steps


# ------------------------------------------------------------ futures as inputs
def test_run_many_futures_as_inputs_defer_until_resolved(svc):
    gate = threading.Event()

    def slow_source(doc):
        gate.wait(10)
        return {"v": doc["v"] * 10}

    def consume(doc):
        return {"sum": doc["a"]["v"] + doc["b"]}

    f_src = svc.register_function(slow_source)
    f_use = svc.register_function(consume)
    upstream = svc.run(f_src, {"v": 4})
    dependent = svc.run(f_use, {"a": upstream, "b": 2})
    assert not dependent.done()          # held back: input still in flight
    gate.set()
    assert dependent.result(10) == {"sum": 42}


def test_futures_as_inputs_propagate_upstream_failure(svc):
    def bad(doc):
        raise RuntimeError("upstream died")

    def consume(doc):
        return doc

    f_bad = svc.register_function(bad)
    f_use = svc.register_function(consume)
    upstream = svc.run(f_bad, {}, max_retries=0)
    dependent = svc.run(f_use, [upstream])
    with pytest.raises(RuntimeError, match="upstream died"):
        dependent.result(10)


# ------------------------------------------------------------ metrics surface
def test_workflow_metrics_in_fabric_snapshot(svc):
    fid = svc.register_function(lambda doc: doc)
    wf = Workflow([
        WorkflowNode("a", fid),
        WorkflowNode("b", fid, deps=["a"]),
    ])
    wf.start(svc, {"v": 1}).wait(30)
    snap = svc.metrics.snapshot()
    assert snap["counters"]["workflow.runs{state=started}"] == 1
    assert snap["counters"]["workflow.runs{state=succeeded}"] == 1
    assert snap["counters"]["workflow.nodes_completed"] == 2
    assert snap["histograms"]["workflow.node_latency_s"]["count"] == 2
