import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)


def make_batch(cfg, B, S, seed=0, dtype=np.float32):
    """Family-correct synthetic batch for a reduced config."""
    r = np.random.default_rng(seed)
    if cfg.family == "vlm":
        return {
            "tokens": r.integers(0, cfg.vocab, (B, S - cfg.n_patches)).astype(np.int32),
            "patches": r.standard_normal((B, cfg.n_patches, cfg.d_model)).astype(dtype),
        }
    batch = {"tokens": r.integers(0, cfg.vocab, (B, S)).astype(np.int32)}
    if cfg.family == "encdec":
        batch["frames"] = r.standard_normal((B, cfg.enc_seq, cfg.d_model)).astype(dtype)
    return batch
