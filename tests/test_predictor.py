"""Predictive routing tier: runtime/transfer predictors, the ``eta_aware``
policy, and ETA-overrun backup speculation."""
import math
import time

import pytest

from repro.core import (
    Forwarder,
    FunctionService,
    RuntimePredictor,
    TaskEnvelope,
    TaskFuture,
    TaskPredictor,
    TransferPredictor,
)


class FakeEndpoint:
    """Routing-only endpoint stub (mirrors tests/test_forwarder.py): accepts
    submissions without executing, so futures stay open and queue state is
    fully controlled by the test."""

    def __init__(self, eid, capacity=4, caps=None):
        self.endpoint_id = eid
        self._capacity = capacity
        self.submitted = []
        if caps is not None:
            self.capabilities = lambda: caps

    def is_alive(self, max_heartbeat_age_s=None):
        return True

    def capacity(self):
        return self._capacity

    def has_warm(self, key):
        return False

    def submit(self, env, future):
        self.submitted.append(env)


# ------------------------------------------------------- rolling averages
def test_rolling_average_uses_last_n_only():
    p = RuntimePredictor(last_n=5)
    # a trending trail: early observations must age out of the window
    for v in range(20):
        p.record("f", "ep", float(v))
    assert p.predict("f", "ep") == pytest.approx(sum(range(15, 20)) / 5)


def test_rolling_average_converges_on_stationary_runtime():
    p = RuntimePredictor(last_n=10)
    for _ in range(50):
        p.record("f", "ep", 0.25)
    assert p.predict("f", "ep") == pytest.approx(0.25)


def test_predictions_are_per_function_endpoint_pair():
    p = RuntimePredictor()
    p.record("f", "fast", 0.01)
    p.record("f", "slow", 1.0)
    p.record("g", "fast", 0.5)
    assert p.predict("f", "fast") == pytest.approx(0.01)
    assert p.predict("f", "slow") == pytest.approx(1.0)
    assert p.predict("g", "fast") == pytest.approx(0.5)


# ------------------------------------------------------- cold-start fallback
def test_cold_start_falls_back_to_cross_endpoint_mean():
    p = RuntimePredictor()
    p.record("f", "a", 0.2)
    p.record("f", "b", 0.4)
    # unmeasured pair: pooled mean across the function's measured endpoints
    assert p.predict("f", "c") == pytest.approx(0.3)
    assert not p.has_history("f", "c")


def test_cold_start_with_no_history_is_none():
    p = RuntimePredictor()
    assert p.predict("f", "anywhere") is None
    assert p.global_mean() is None


def test_cold_start_counter_increments():
    from repro.core import MetricsRegistry

    m = MetricsRegistry()
    p = RuntimePredictor(metrics=m)
    p.record("f", "a", 0.1)
    p.predict("f", "b")  # fallback path
    p.predict("f", "a")  # direct path — must NOT count
    assert m.counter("predictor.cold_starts").value == 1
    assert m.counter("predictor.observations").value == 1


# ------------------------------------------------------- transfer estimator
def test_transfer_estimate_scales_with_bytes():
    t = TransferPredictor(bandwidth_bps=1 << 30, latency_s=1e-3)
    small = t.estimate(1 << 10)
    big = t.estimate(1 << 30)
    assert small == pytest.approx(1e-3 + (1 << 10) / (1 << 30))
    assert big == pytest.approx(1e-3 + 1.0)
    assert big > 100 * small


def test_transfer_record_adapts_bandwidth():
    t = TransferPredictor(bandwidth_bps=1 << 30, latency_s=0.0, alpha=1.0)
    t.record(1 << 20, 1.0)  # observed: 1 MiB took a full second
    assert t.estimate(1 << 20) == pytest.approx(1.0)


# ------------------------------------------------------- ETA composition
def test_eta_adds_queue_delay_and_transfer():
    tp = TaskPredictor(transfer=TransferPredictor(bandwidth_bps=1 << 20,
                                                  latency_s=0.0))
    tp.record("f", "ep", 0.1)
    idle = tp.eta("f", "ep", transfer_bytes=0, outstanding=0, capacity=4)
    assert idle == pytest.approx(0.1)
    queued = tp.eta("f", "ep", transfer_bytes=0, outstanding=8, capacity=4)
    assert queued == pytest.approx(0.1 + 8 * 0.1 / 4)
    moving = tp.eta("f", "ep", transfer_bytes=1 << 20, outstanding=0, capacity=4)
    assert moving == pytest.approx(0.1 + 1.0)


def test_eta_error_feeds_pessimism_and_overrun_bound():
    tp = TaskPredictor(queue_error_alpha=1.0)
    tp.record("f", "ep", 0.1)
    tp.observe_eta("ep", predicted_s=0.1, actual_s=0.5)  # 0.4 s overrun
    assert tp.queue_error("ep") == pytest.approx(0.4)
    # underruns must not produce negative corrections
    tp.observe_eta("ep", predicted_s=0.5, actual_s=0.1)
    assert tp.queue_error("ep") == pytest.approx(0.0)
    bound = tp.overrun_bound("ep", predicted_s=0.2, factor=3.0, min_age_s=0.05)
    assert bound == pytest.approx(max(0.05, 0.2 * 3.0 + tp.queue_error("ep")))


# ------------------------------------------------------- eta_aware routing
def _prime(fwd, fid, fast, slow, fast_s=0.01, slow_s=1.0):
    for _ in range(10):
        fwd.predictor.record(fid, fast.endpoint_id, fast_s)
        fwd.predictor.record(fid, slow.endpoint_id, slow_s)


def test_eta_aware_prefers_measured_fast_endpoint():
    fast, slow = FakeEndpoint("fast", capacity=4), FakeEndpoint("slow", capacity=4)
    fwd = Forwarder(policy="eta_aware", seed=0)
    fwd.register(fast)
    fwd.register(slow)
    try:
        _prime(fwd, "f", fast, slow)
        picks = []
        for i in range(8):
            fut = TaskFuture(f"t{i}")
            picks.append(fwd.submit(
                TaskEnvelope(task_id=f"t{i}", function_id="f", payload=b""),
                fut,
            ))
        # the fast endpoint's queue has to back up 100 deep before its ETA
        # matches one slow execution, so every pick lands fast
        assert picks == ["fast"] * 8
    finally:
        fwd.shutdown()


def test_eta_aware_explores_unmeasured_pairs_first():
    a, b = FakeEndpoint("a"), FakeEndpoint("b")
    fwd = Forwarder(policy="eta_aware", seed=0)
    fwd.register(a)
    fwd.register(b)
    try:
        fwd.predictor.record("f", "a", 0.01)
        fut = TaskFuture("t0")
        picked = fwd.submit(
            TaskEnvelope(task_id="t0", function_id="f", payload=b""), fut
        )
        assert picked == "b"  # unmeasured pair wins over any measured ETA
    finally:
        fwd.shutdown()


def test_eta_aware_beats_random_p99_on_skewed_fabric():
    """Deterministic replay: K tasks over a 0.01 s endpoint (cap 8) and a
    1.0 s endpoint (cap 1). Synthetic completion time of the j-th task
    assigned to an endpoint is runtime * ceil((j+1)/capacity) — pure queueing,
    no sleeping — and eta_aware's p99 must beat random's."""
    runtimes = {"fast": 0.01, "slow": 1.0}
    caps = {"fast": 8, "slow": 1}

    def simulate(policy, seed=3):
        eps = [FakeEndpoint(e, capacity=caps[e]) for e in ("fast", "slow")]
        fwd = Forwarder(policy=policy, seed=seed)
        for ep in eps:
            fwd.register(ep)
        try:
            if fwd.predictor is not None:  # random routes blind by design
                _prime(fwd, "f", eps[0], eps[1],
                       fast_s=runtimes["fast"], slow_s=runtimes["slow"])
            counts = {"fast": 0, "slow": 0}
            lats = []
            for i in range(64):
                fut = TaskFuture(f"t{i}")
                eid = fwd.submit(
                    TaskEnvelope(task_id=f"t{i}", function_id="f", payload=b""),
                    fut,
                )
                counts[eid] += 1
                lats.append(
                    runtimes[eid] * math.ceil(counts[eid] / caps[eid])
                )
        finally:
            fwd.shutdown()
        lats.sort()
        return lats[int(0.99 * (len(lats) - 1))]

    assert simulate("eta_aware") < simulate("random")


# ------------------------------------------------------- speculation wiring
def sleepy(doc):
    time.sleep(doc.get("t", 0.0))
    return doc.get("i", 0)


def test_eta_overrun_trips_backup_speculation():
    """A live mini-fabric with an aggressive overrun bound: backups launch,
    every task still completes exactly once, and the journal-facing counter
    contract holds (losers dedupe, never double-commit)."""
    fwd = Forwarder(
        policy="eta_aware",
        speculation=True,
        speculation_eta_factor=0.5,   # trip on ~half the predicted ETA
        speculation_min_age_s=0.01,
        watchdog_interval_s=0.01,
    )
    svc = FunctionService(forwarder=fwd)
    svc.make_endpoint("s0", n_executors=1, workers_per_executor=2)
    svc.make_endpoint("s1", n_executors=1, workers_per_executor=2)
    fid = svc.register_function(sleepy, name="spec_sleepy")
    try:
        outs = [
            f.result(30)
            for f in svc.batch_run(
                fid, [{"i": i, "t": 0.05} for i in range(12)]
            )
        ]
        assert sorted(outs) == list(range(12))
        deadline = time.monotonic() + 2.0
        while fwd.backups_launched == 0 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert fwd.backups_launched > 0
        assert fwd.stats()["speculation"] is True
        assert (
            svc.metrics.counter("predictor.backups_launched").value
            == fwd.backups_launched
        )
    finally:
        svc.shutdown()


def test_speculation_never_double_completes_with_journal(tmp_path):
    fwd = Forwarder(
        policy="eta_aware",
        speculation=True,
        speculation_eta_factor=0.5,
        speculation_min_age_s=0.01,
        watchdog_interval_s=0.01,
    )
    svc = FunctionService(forwarder=fwd, journal_dir=str(tmp_path / "wal"))
    svc.make_endpoint("j0", n_executors=1, workers_per_executor=2)
    svc.make_endpoint("j1", n_executors=1, workers_per_executor=2)
    fid = svc.register_function(sleepy, name="spec_journaled")
    try:
        futs = svc.batch_run(fid, [{"i": i, "t": 0.04} for i in range(10)])
        assert sorted(f.result(30) for f in futs) == list(range(10))
        time.sleep(0.2)  # let speculation losers drain through dedupe
        st = svc.journal.state()
        assert st.duplicate_completions == 0
        assert all(st.tasks[f.task_id].terminal for f in futs)
        # backup task ids ("<tid>#eta") must never appear as journal keys:
        # backups are never journaled, they only race toward the canonical id
        assert not any("#eta" in tid for tid in st.tasks)
    finally:
        svc.shutdown()


def test_speculation_respects_requirements():
    """A backup may only land on an endpoint satisfying the envelope's
    capability requirements — if no second such endpoint exists, no backup."""
    gpu = FakeEndpoint("gpu", capacity=2, caps={"gpu"})
    cpu = FakeEndpoint("cpu", capacity=2, caps=set())
    fwd = Forwarder(policy="least_outstanding", speculation=True, seed=0)
    fwd.register(gpu)
    fwd.register(cpu)
    try:
        env = TaskEnvelope(
            task_id="t0", function_id="f", payload=b"",
            requirements=("gpu",),
        )
        fut = TaskFuture("t0")
        assert fwd.submit(env, fut) == "gpu"
        assert fwd._launch_backup(env, fwd._records["gpu"]) is False
        assert fwd.backups_launched == 0
    finally:
        fwd.shutdown()
