"""End-to-end behaviour of the FaaS platform (the paper's system)."""
import time

import numpy as np
import pytest

from repro.core import (
    AuthError,
    FunctionService,
    TaskState,
    TokenAuthority,
    SCOPE_INVOKE,
    SCOPE_REGISTER_ENDPOINT,
    SCOPE_REGISTER_FUNCTION,
)


@pytest.fixture()
def service():
    svc = FunctionService()
    svc.make_endpoint("test-ep", n_executors=2, workers_per_executor=2, prefetch=2,
                      policy="least_loaded")
    yield svc
    svc.shutdown()


def _double(doc):
    return {"y": np.asarray(doc["x"]) * 2}


def test_register_and_run_roundtrip(service):
    fid = service.register_function(_double, name="double")
    fut = service.run(fid, {"x": np.arange(4)})
    out = fut.result(timeout=10)
    np.testing.assert_array_equal(out["y"], [0, 2, 4, 6])
    assert fut.state == TaskState.SUCCESS


def test_sync_invocation(service):
    fid = service.register_function(_double)
    out = service.run(fid, {"x": np.ones(3)}, sync=True, timeout=10)
    np.testing.assert_array_equal(out["y"], [2, 2, 2])


def test_latency_breakdown_monotonic(service):
    fid = service.register_function(_double)
    fut = service.run(fid, {"x": np.arange(2)})
    fut.result(10)
    b = fut.latency_breakdown()
    assert set(b) == {"t_c", "t_w", "t_m", "t_e", "total"}
    assert all(v >= 0 for v in b.values())
    assert b["total"] >= b["t_e"]
    assert abs(b["total"] - sum(b[k] for k in ("t_c", "t_w", "t_m", "t_e"))) < 1e-6


def test_map_many_tasks(service):
    fid = service.register_function(_double)
    outs = service.map(fid, [{"x": np.full(2, i)} for i in range(20)], timeout=30)
    assert [int(o["y"][0]) for o in outs] == [2 * i for i in range(20)]


def test_function_errors_surface(service):
    def boom(doc):
        raise ValueError("kaboom")

    fid = service.register_function(boom, name="boom")
    fut = service.run(fid, {}, max_retries=0)
    with pytest.raises(ValueError, match="kaboom"):
        fut.result(10)
    assert fut.state == TaskState.FAILED


def test_unknown_function_rejected(service):
    with pytest.raises(KeyError):
        service.run("deadbeef", {})


def test_jax_jit_function_warm_faster_than_cold(service):
    import jax.numpy as jnp

    def mm(doc):
        return {"z": jnp.dot(doc["a"], doc["a"].T).sum()}

    fid = service.register_function(mm, name="mm", jax_jit=True)
    p = {"a": np.ones((128, 128), np.float32)}
    t0 = time.monotonic()
    service.run(fid, p).result(60)
    cold = time.monotonic() - t0
    t0 = time.monotonic()
    service.run(fid, p).result(60)
    warm = time.monotonic() - t0
    assert warm < cold, (warm, cold)


def test_auth_scopes_enforced():
    authority = TokenAuthority()
    svc = FunctionService(authority=authority)
    owner = authority.issue("alice", (SCOPE_REGISTER_FUNCTION, SCOPE_INVOKE,
                                      SCOPE_REGISTER_ENDPOINT))
    svc.make_endpoint("ep", n_executors=1, workers_per_executor=1, token=owner)
    fid = svc.register_function(_double, token=owner)

    invoker = authority.issue("bob", (SCOPE_INVOKE,))
    with pytest.raises(AuthError):
        svc.run(fid, {"x": np.ones(1)}, token=invoker)  # private function

    with pytest.raises(AuthError):
        svc.run(fid, {"x": np.ones(1)})  # no token

    out = svc.run(fid, {"x": np.ones(1)}, token=owner, sync=True, timeout=10)
    np.testing.assert_array_equal(out["y"], [2])
    svc.shutdown()


def test_public_function_cross_user():
    authority = TokenAuthority()
    svc = FunctionService(authority=authority)
    owner = authority.issue("alice", (SCOPE_REGISTER_FUNCTION, SCOPE_INVOKE,
                                      SCOPE_REGISTER_ENDPOINT))
    svc.make_endpoint("ep", n_executors=1, workers_per_executor=1, token=owner)
    fid = svc.register_function(_double, token=owner, public=True)
    bob = authority.issue("bob", (SCOPE_INVOKE,))
    out = svc.run(fid, {"x": np.ones(1)}, token=bob, sync=True, timeout=10)
    np.testing.assert_array_equal(out["y"], [2])
    svc.shutdown()


def test_endpoint_stats_shape(service):
    fid = service.register_function(_double)
    service.map(fid, [{"x": np.ones(1)}] * 5, timeout=10)
    stats = service.stats()
    assert stats["functions"] >= 1
    ep = list(stats["endpoints"].values())[0]
    assert ep["completed"] >= 5
    assert ep["queue_depth"] == 0
