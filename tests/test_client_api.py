"""Unified client surface: wait / get_result, stdlib-aligned TaskFuture,
and the collapsed ``_submit`` submission path."""
from __future__ import annotations

import concurrent.futures as cf
import threading
import time

import pytest

from repro.core import (
    ALL_COMPLETED,
    ALWAYS,
    ANY_COMPLETED,
    FunctionService,
    Invocation,
    TaskFuture,
    TaskState,
    get_result,
    wait,
)


def add_one(x):
    return x + 1


def napper(doc):
    time.sleep(doc["t"])
    return doc["i"]


def _completed(task_id, value):
    f = TaskFuture(task_id)
    f.set_result(value)
    return f


def _later(task_id, value, delay):
    f = TaskFuture(task_id)
    threading.Timer(delay, f.set_result, args=(value,)).start()
    return f


# ---------------------------------------------------------------------------
# wait()
# ---------------------------------------------------------------------------
class TestWait:
    def test_all_completed_partitions_in_input_order(self):
        fs = [_later("b", 2, 0.05), _completed("a", 1), _later("c", 3, 0.1)]
        done, not_done = wait(fs)
        assert [f.task_id for f in done] == ["b", "a", "c"]
        assert not_done == []

    def test_any_completed_returns_on_first(self):
        slow = TaskFuture("slow")  # never resolves
        fast = _later("fast", 1, 0.02)
        done, not_done = wait([slow, fast], return_when=ANY_COMPLETED,
                              timeout=5)
        assert fast in done and slow in not_done

    def test_always_returns_immediately(self):
        pending = TaskFuture("pending")
        done, not_done = wait([pending, _completed("d", 0)],
                              return_when=ALWAYS)
        assert [f.task_id for f in done] == ["d"]
        assert [f.task_id for f in not_done] == ["pending"]

    def test_timeout_returns_partial_partition(self):
        pending = TaskFuture("pending")
        t0 = time.monotonic()
        done, not_done = wait([pending, _completed("d", 0)], timeout=0.05)
        assert time.monotonic() - t0 < 1.0
        assert [f.task_id for f in done] == ["d"]
        assert not_done == [pending]
        # the straggler's callback list must not leak the wait's observer
        assert pending._callbacks == []

    def test_throw_except_raises_first_failure(self):
        bad = TaskFuture("bad")
        bad.set_exception(RuntimeError("boom"))
        with pytest.raises(RuntimeError, match="boom"):
            wait([_completed("ok", 1), bad])
        done, _ = wait([_completed("ok", 1), bad], throw_except=False)
        assert len(done) == 2

    def test_single_future_accepted(self):
        done, not_done = wait(_completed("solo", 5))
        assert len(done) == 1 and not_done == []

    def test_empty_iterable(self):
        assert wait([]) == ([], [])

    def test_unknown_return_when_rejected(self):
        with pytest.raises(ValueError, match="return_when"):
            wait([], return_when="SOME_COMPLETED")

    def test_mixes_stdlib_futures(self):
        std = cf.Future()
        std.set_result(11)
        ours = _completed("m", 22)
        done, _ = wait([std, ours])
        assert [12 - 1, 22] == [done[0].result(), done[1].result(0)]

    def test_stdlib_cancelled_future_raises_cancelled(self):
        std = cf.Future()
        std.cancel()
        with pytest.raises(cf.CancelledError):
            wait([std])


# ---------------------------------------------------------------------------
# get_result()
# ---------------------------------------------------------------------------
class TestGetResult:
    def test_single_future_bare_result(self):
        assert get_result(_completed("s", 9)) == 9

    def test_ordered_results(self):
        fs = [_later("x", 10, 0.03), _completed("y", 20)]
        assert get_result(fs) == [10, 20]

    def test_timeout_raises(self):
        with pytest.raises(TimeoutError, match="1 of 2"):
            get_result([TaskFuture("never"), _completed("z", 1)],
                       timeout=0.05)

    def test_throw_except_false_yields_none_placeholders(self):
        bad = TaskFuture("bad")
        bad.set_exception(ValueError("nope"))
        cancelled = TaskFuture("c")
        cancelled.cancel()
        out = get_result([_completed("g", 7), bad, cancelled],
                         throw_except=False)
        assert out == [7, None, None]

    def test_throw_except_raises(self):
        bad = TaskFuture("bad")
        bad.set_exception(ValueError("nope"))
        with pytest.raises(ValueError, match="nope"):
            get_result([bad])


# ---------------------------------------------------------------------------
# TaskFuture: concurrent.futures alignment
# ---------------------------------------------------------------------------
class TestFutureAlignment:
    def test_cancel_resolves_with_cancelled_error(self):
        f = TaskFuture("t")
        assert f.cancel() is True
        assert f.cancelled() and f.done()
        assert f.state is TaskState.CANCELLED
        with pytest.raises(cf.CancelledError):
            f.result(0)
        assert isinstance(f.exception(0), cf.CancelledError)

    def test_cancel_after_completion_fails(self):
        f = _completed("t", 1)
        assert f.cancel() is False
        assert not f.cancelled()
        assert f.result(0) == 1

    def test_late_result_after_cancel_dedupes(self):
        f = TaskFuture("t")
        f.cancel()
        assert f.set_result(42) is False  # the remote result arrives late
        assert f.cancelled()

    def test_running_reflects_dispatch_states(self):
        f = TaskFuture("t")
        assert not f.running()
        f.set_state(TaskState.DISPATCHED)
        assert f.running()
        f.set_state(TaskState.RUNNING)
        assert f.running()
        f.set_result(1)
        assert not f.running()


# ---------------------------------------------------------------------------
# The collapsed submission path + end-to-end client surface
# ---------------------------------------------------------------------------
class TestUnifiedSubmit:
    @pytest.fixture()
    def svc(self):
        svc = FunctionService()
        svc.make_endpoint("ep", n_executors=2)
        yield svc
        svc.shutdown()

    def test_run_batch_run_run_many_share_submit(self, svc, monkeypatch):
        fid = svc.register_function(add_one)
        calls = []
        orig = FunctionService._submit

        def spy(self, invocations, token=None):
            calls.append(len(invocations))
            return orig(self, invocations, token=token)

        monkeypatch.setattr(FunctionService, "_submit", spy)
        assert svc.run(fid, 1).result(10) == 2
        assert [f.result(10) for f in svc.batch_run(fid, [1, 2])] == [2, 3]
        assert svc.run_many([Invocation(fid, 5)])[0].result(10) == 6
        assert calls == [1, 2, 1]  # every public name funnels through _submit

    def test_wait_and_get_result_over_fabric_futures(self, svc):
        fid = svc.register_function(napper)
        futs = svc.batch_run(
            fid, [{"i": i, "t": 0.01 * (i % 3)} for i in range(6)]
        )
        done, not_done = wait(futs, return_when=ANY_COMPLETED, timeout=10)
        assert done
        assert get_result(futs, timeout=10) == list(range(6))

    def test_get_result_single_fabric_future(self, svc):
        fid = svc.register_function(add_one)
        assert get_result(svc.run(fid, 41), timeout=10) == 42
