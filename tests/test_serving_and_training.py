"""Integration: continuous-batching serve engine, FaaS-driven training loop
with checkpoint/restart, and automation flows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import ActionStep, Flow, FunctionService
from repro.models.model import Model
from repro.serving.engine import ServeEngine
from repro.serving.kv_cache import cache_bytes
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import TrainConfig, Trainer


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced("qwen1.5-0.5b").with_(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _greedy_reference(model, params, prompt, n_new):
    """Sequential full-recompute greedy decoding (no cache) — the oracle for
    the engine's continuous batching."""
    toks = list(np.asarray(prompt, np.int32))
    out = []
    for _ in range(n_new):
        h, _ = model.forward(params, {"tokens": jnp.asarray([toks], jnp.int32)})
        logits = model._logits(params, h)[0, -1]
        nxt = int(jnp.argmax(logits))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_matches_sequential_greedy(small_model):
    model, params = small_model
    engine = ServeEngine(model, params, max_batch=2, max_len=48)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, model.cfg.vocab, n) for n in (5, 9, 7)]
    reqs = [engine.submit(p, max_new_tokens=4) for p in prompts]
    engine.run_until_drained(timeout=120)
    for p, r in zip(prompts, reqs):
        assert r.done.is_set()
        expected = _greedy_reference(model, params, p, 4)
        assert r.tokens == expected, (r.tokens, expected)


def test_engine_continuous_batching_slots_reused(small_model):
    model, params = small_model
    engine = ServeEngine(model, params, max_batch=2, max_len=32)
    rng = np.random.default_rng(1)
    reqs = [engine.submit(rng.integers(0, model.cfg.vocab, 4), max_new_tokens=3)
            for _ in range(5)]  # 5 requests > 2 slots
    engine.run_until_drained(timeout=120)
    assert all(r.done.is_set() and len(r.tokens) == 3 for r in reqs)
    assert engine.stats()["pending"] == 0


def test_cache_bytes_analytical():
    cfg = get_reduced("qwen1.5-0.5b")
    b = cache_bytes(cfg, batch=2, seq_len=64)
    expected = cfg.n_layers * 2 * 64 * 2 * cfg.n_kv_heads * cfg.hd * 2
    assert b == expected
    # MLA caches are compressed: much smaller than GQA at same dims
    mla_cfg = get_reduced("minicpm3-4b")
    full = mla_cfg.n_layers * 2 * 64 * 2 * mla_cfg.n_kv_heads * mla_cfg.hd * 2
    assert cache_bytes(mla_cfg, 2, 64) < full / 4


def test_trainer_loss_decreases_and_checkpoints(tmp_path):
    cfg = get_reduced("qwen1.5-0.5b").with_(dtype="float32")
    model = Model(cfg)
    ocfg = OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=30)
    tcfg = TrainConfig(steps=12, batch=2, seq=32, ckpt_every=6,
                       ckpt_dir=str(tmp_path), log_every=0)
    trainer = Trainer(model, ocfg, tcfg)
    history = trainer.run()
    assert len(history) == 12
    assert history[-1]["loss"] < history[0]["loss"]
    assert trainer.ckpt.latest_step() == 12


def test_trainer_restart_resumes_from_checkpoint(tmp_path):
    cfg = get_reduced("qwen1.5-0.5b").with_(dtype="float32")
    model = Model(cfg)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=30)
    tcfg = TrainConfig(steps=6, batch=2, seq=32, ckpt_every=3,
                       ckpt_dir=str(tmp_path), log_every=0)
    Trainer(model, ocfg, tcfg).run()
    # "controller restart": a new trainer resumes at step 6 and continues
    tcfg2 = TrainConfig(steps=10, batch=2, seq=32, ckpt_every=5,
                        ckpt_dir=str(tmp_path), log_every=0)
    t2 = Trainer(model, ocfg, tcfg2)
    assert t2.step == 6
    history = t2.run()
    assert len(history) == 4  # only steps 7..10 re-run
    assert t2.step == 10


def test_trainer_through_faas_service(tmp_path):
    cfg = get_reduced("qwen2-0.5b").with_(dtype="float32")
    model = Model(cfg)
    svc = FunctionService()
    svc.make_endpoint("train", n_executors=1, workers_per_executor=1)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    tcfg = TrainConfig(steps=4, batch=2, seq=16, ckpt_dir=None, log_every=0)
    trainer = Trainer(model, ocfg, tcfg, service=svc)
    history = trainer.run()
    assert len(history) == 4
    assert all(np.isfinite(h["loss"]) for h in history)
    # the steps really went through the endpoint
    ep = list(svc.endpoints.values())[0]
    assert ep.completed >= 4
    svc.shutdown()


def test_automation_flow_pipeline():
    svc = FunctionService()
    svc.make_endpoint("flow", n_executors=1, workers_per_executor=2)

    def extract(doc):
        return {"values": np.asarray(doc["raw"]) * 1.0}

    def reduce_step(doc):
        return {"mean": float(np.mean(doc["values"]))}

    f1 = svc.register_function(extract)
    f2 = svc.register_function(reduce_step)
    flow = Flow([ActionStep(f1, name="extract"), ActionStep(f2, name="reduce")])
    run = flow.start(svc, {"raw": np.arange(10)})
    result = Flow.wait(run, timeout=30)
    assert result["mean"] == 4.5
    assert run.state == "SUCCEEDED"
    assert len(run.history) == 2
    svc.shutdown()


def test_engine_serve_forever_handles_trickling_requests(small_model):
    import threading
    import time as _time

    model, params = small_model
    engine = ServeEngine(model, params, max_batch=2, max_len=48)
    stop = threading.Event()
    t = threading.Thread(target=engine.serve_forever, args=(stop,), daemon=True)
    t.start()
    rng = np.random.default_rng(2)
    reqs = []
    for i in range(4):  # trickle: would defeat run_until_drained's exit check
        reqs.append(engine.submit(rng.integers(0, model.cfg.vocab, 5),
                                  max_new_tokens=3))
        _time.sleep(0.05)
    for r in reqs:
        assert r.done.wait(120), "request never completed under serve_forever"
        assert len(r.tokens) == 3
    stop.set()
    t.join(timeout=5)
