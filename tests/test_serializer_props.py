"""Serializer hardening: writable arrays out of unpackb, plus hypothesis
round-trip properties over 0-d, Fortran-order, and nested-pytree payloads."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on clean environments
    from _hypothesis_stub import given, settings, st

from repro.core.serializer import packb, payload_hash, unpackb


def roundtrip(obj):
    return unpackb(packb(obj))


# ------------------------------------------------------------ writability
def test_unpacked_array_is_writable():
    """Seed regression: unpackb built arrays as np.frombuffer views over the
    immutable wire bytes, so functions mutating their inputs crashed with
    'assignment destination is read-only'."""
    arr = roundtrip(np.arange(6, dtype=np.float32).reshape(2, 3))
    assert arr.flags.writeable
    arr[0, 0] = 99.0                      # must not raise
    assert arr[0, 0] == 99.0


def test_unpacked_nested_arrays_are_writable():
    doc = {"frames": [np.zeros(3), np.ones((2, 2), dtype=np.int64)],
           "meta": (np.array(5),)}
    out = roundtrip(doc)
    for leaf in (out["frames"][0], out["frames"][1], out["meta"][0]):
        assert leaf.flags.writeable
        leaf[...] = 1


def test_zero_d_array_roundtrip():
    arr = np.array(3.5)
    out = roundtrip(arr)
    assert out.shape == ()
    assert out.dtype == arr.dtype
    assert out == 3.5
    assert out.flags.writeable


def test_fortran_order_array_roundtrip():
    arr = np.asfortranarray(np.arange(12, dtype=np.float64).reshape(3, 4))
    assert arr.flags.f_contiguous and not arr.flags.c_contiguous
    out = roundtrip(arr)
    np.testing.assert_array_equal(out, arr)   # values survive the C-order wire
    assert out.flags.writeable


def test_nested_pytree_roundtrip():
    doc = {
        "a": [1, 2.5, "x", None, True],
        "b": (np.arange(4, dtype=np.int32), {"c": complex(1, -2)}),
        "s": {3, 1, 2},
    }
    out = roundtrip(doc)
    assert out["a"] == [1, 2.5, "x", None, True]
    np.testing.assert_array_equal(out["b"][0], np.arange(4, dtype=np.int32))
    # tuples ride the wire as msgpack arrays and come back as lists
    assert isinstance(out["b"], list)
    assert out["b"][1]["c"] == complex(1, -2)
    assert out["s"] == {3, 1, 2}


# ------------------------------------------------------------ zero-copy fast path
def test_readonly_unpack_skips_the_copy():
    """unpackb(..., writable=False) returns read-only frombuffer views over
    the wire bytes — no per-array copy — for callers that never hand the
    value to user code (decoded caches, ref scans, unpack-to-repack hops)."""
    arr = np.arange(8, dtype=np.float32).reshape(2, 4)
    out = unpackb(packb(arr), writable=False)
    assert not out.flags.writeable
    assert not out.flags.owndata  # a view over the wire buffer, not a copy
    np.testing.assert_array_equal(out, arr)


def test_readonly_unpack_propagates_through_nesting():
    doc = {"a": [np.zeros(3)], "b": (np.ones((2, 2)),), "c": 5}
    out = unpackb(packb(doc), writable=False)
    assert not out["a"][0].flags.writeable
    assert not out["b"][0].flags.writeable
    assert out["c"] == 5


def test_writable_default_is_unchanged():
    """The default API still copies: both decodes see equal values, only the
    flag differs."""
    payload = {"x": np.arange(16, dtype=np.int64)}
    wire = packb(payload)
    rw, ro = unpackb(wire), unpackb(wire, writable=False)
    np.testing.assert_array_equal(rw["x"], ro["x"])
    assert rw["x"].flags.writeable
    rw["x"][0] = -1  # must not raise — and must not leak into the ro view
    assert ro["x"][0] == 0


def test_fresh_copy_of_readonly_decode_is_writable():
    """The endpoint decoded-value cache decodes read-only, then hands out
    _fresh_copy per task — the hand-out must come back writable."""
    from repro.core.datastore import _fresh_copy

    ro = unpackb(packb({"x": np.arange(4)}), writable=False)
    handout = _fresh_copy(ro)
    assert handout["x"].flags.writeable
    handout["x"][0] = 9
    assert ro["x"][0] == 0


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_readonly_and_writable_decodes_agree(data):
    payload = data.draw(st.one_of(st.just(None), st.text(max_size=10)))
    arr = build_array(data.draw(array_specs))
    wire = packb({"p": payload, "a": arr})
    rw, ro = unpackb(wire), unpackb(wire, writable=False)
    assert rw["p"] == ro["p"]
    np.testing.assert_array_equal(rw["a"], ro["a"])
    assert rw["a"].flags.writeable and not ro["a"].flags.writeable


# ------------------------------------------------------------ hypothesis props
_DTYPES = (np.float32, np.float64, np.int32, np.int64, np.uint8, np.bool_)

array_specs = st.tuples(
    st.sampled_from(range(len(_DTYPES))),
    st.lists(st.integers(min_value=0, max_value=4), min_size=0, max_size=3),
    st.booleans(),  # Fortran order
)


def build_array(spec):
    dtype_idx, shape, fortran = spec
    dtype = _DTYPES[dtype_idx]
    size = int(np.prod(shape)) if shape else 1
    arr = (np.arange(size) % 127).reshape(shape).astype(dtype)
    return np.asfortranarray(arr) if fortran and arr.ndim > 1 else arr


scalars = st.one_of(
    st.none(),
    st.booleans(),
    st.integers(min_value=-(2**53), max_value=2**53),
    st.floats(allow_nan=False, allow_infinity=False, width=64),
    st.text(max_size=20),
)

payloads = st.recursive(
    st.one_of(scalars, array_specs.map(build_array)),
    lambda children: st.one_of(
        st.lists(children, max_size=4),
        st.tuples(children, children),
        st.dictionaries(st.text(max_size=8), children, max_size=4),
    ),
    max_leaves=12,
)


def assert_payload_equal(a, b):
    if isinstance(a, np.ndarray):
        assert isinstance(b, np.ndarray)
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(b, a)
        assert b.flags.writeable
    elif isinstance(a, dict):
        assert set(a) == set(b)
        for k in a:
            assert_payload_equal(a[k], b[k])
    elif isinstance(a, (list, tuple)):
        # tuples come back as lists (msgpack array on the wire)
        assert isinstance(b, (list, tuple)) and len(a) == len(b)
        for x, y in zip(a, b):
            assert_payload_equal(x, y)
    else:
        assert a == b


@settings(max_examples=60, deadline=None)
@given(payloads)
def test_roundtrip_property(payload):
    assert_payload_equal(payload, roundtrip(payload))


@settings(max_examples=60, deadline=None)
@given(payloads)
def test_payload_hash_is_stable_and_roundtrip_invariant(payload):
    # packing is canonical: hashing the payload twice, or hashing its
    # round-tripped self, must agree (memo keys survive the wire)
    h = payload_hash(payload)
    assert payload_hash(payload) == h
    assert payload_hash(roundtrip(payload)) == h


@settings(max_examples=40, deadline=None)
@given(array_specs)
def test_every_unpacked_array_is_writable(spec):
    arr = build_array(spec)
    out = roundtrip(arr)
    assert out.flags.writeable
    if out.size:
        out.flat[0] = 0                   # must not raise


# ------------------------------------------------------------ DataRef props
from repro.core.datastore import (  # noqa: E402
    DataRef,
    InMemoryStore,
    resolve_payload,
    spill_payload,
)

_HEX = "0123456789abcdef"

datarefs = st.builds(
    DataRef,
    key=st.text(alphabet=_HEX, min_size=64, max_size=64),
    size=st.integers(min_value=0, max_value=2**40),
    locations=st.lists(
        st.sampled_from(["mem://a", "mem://b", "fs:///tmp/x", "fs:///tmp/y"]),
        max_size=3, unique=True,
    ).map(tuple),
)

# the tentpole payload space: DataRefs anywhere a leaf can live
ref_payloads = st.recursive(
    st.one_of(scalars, datarefs, array_specs.map(build_array)),
    lambda children: st.one_of(
        st.lists(children, max_size=4),
        st.dictionaries(st.text(max_size=8), children, max_size=4),
    ),
    max_leaves=10,
)


def assert_ref_payload_equal(a, b):
    if isinstance(a, DataRef):
        assert a == b  # frozen dataclass equality: key, size, and locations
    elif isinstance(a, dict):
        assert set(a) == set(b)
        for k in a:
            assert_ref_payload_equal(a[k], b[k])
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b)
        for x, y in zip(a, b):
            assert_ref_payload_equal(x, y)
    else:
        assert_payload_equal(a, b)


@settings(max_examples=60, deadline=None)
@given(ref_payloads)
def test_dataref_payload_roundtrip_identity(payload):
    """DataRef leaves nested in dicts/lists/arrays survive the wire exactly —
    key, declared size, and every advertised location."""
    assert_ref_payload_equal(payload, roundtrip(payload))


@settings(max_examples=60, deadline=None)
@given(ref_payloads)
def test_dataref_payload_hash_stability(payload):
    h = payload_hash(payload)
    assert payload_hash(payload) == h
    assert payload_hash(roundtrip(payload)) == h


def _strip_locations(obj):
    if isinstance(obj, DataRef):
        return DataRef(key=obj.key, size=obj.size, locations=("mem://moved",))
    if isinstance(obj, dict):
        return {k: _strip_locations(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_strip_locations(v) for v in obj]
    return obj


@settings(max_examples=60, deadline=None)
@given(ref_payloads)
def test_payload_hash_ignores_where_data_lives(payload):
    """Memo keys must be location-free: rewriting every ref's location set
    (data migrated to another store) leaves the payload hash unchanged."""
    assert payload_hash(_strip_locations(payload)) == payload_hash(payload)


@settings(max_examples=40, deadline=None)
@given(
    st.integers(min_value=1, max_value=512),   # array length
    st.integers(min_value=-2, max_value=2),    # threshold offset from nbytes
)
def test_spill_threshold_boundary_stability(n, delta):
    """spill(resolve) is the identity around the threshold boundary: leaves
    spill iff their in-memory size >= threshold, and resolving restores the
    exact array either way."""
    store = InMemoryStore(register=False)
    arr = np.arange(n, dtype=np.float64)
    payload = {"x": arr, "tag": n}
    threshold = max(1, arr.nbytes + delta)
    spilled, refs = spill_payload(payload, store, threshold)
    should_spill = arr.nbytes >= threshold
    assert isinstance(spilled["x"], DataRef) == should_spill
    assert len(refs) == (1 if should_spill else 0)
    resolved = resolve_payload(spilled)
    np.testing.assert_array_equal(resolved["x"], arr)
    assert resolved["tag"] == n


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=256))
def test_spill_is_idempotent_and_content_addressed(n):
    """Spilling the same payload twice lands on the same blob key (content
    addressing), and re-spilling an already-spilled payload is a no-op that
    still reports the existing refs."""
    store = InMemoryStore(register=False)
    payload = {"x": np.full(n, 7, dtype=np.int64)}
    s1, r1 = spill_payload(payload, store, threshold=1)
    s2, r2 = spill_payload(payload, store, threshold=1)
    assert [r.key for r in r1] == [r.key for r in r2]
    assert len(store) == 1
    s3, r3 = spill_payload(s1, store, threshold=1)
    assert s3["x"] == s1["x"]
    assert [r.key for r in r3] == [r.key for r in r1]
