"""Quickstart: the funcX usage pattern from the paper (§4), in funcJAX.

    PYTHONPATH=src python examples/quickstart.py

Registers a Python function, invokes it synchronously and asynchronously on a
local endpoint, shows memoization, user-driven batching, and the Fig.-5
latency breakdown.

Expected output: the sync/async invocation results, a memoized re-invocation
returning in ~0 ms with state MEMOIZED, the batched fan-out results, and a
per-invocation t_c/t_w/t_m/t_e latency table (t_e dominating for the sleep
task).
"""
import time

import numpy as np

from repro.core import FunctionService


def main() -> None:
    # the cloud-hosted funcX service + one endpoint ("any laptop, cluster,
    # cloud or supercomputer where the endpoint software runs")
    service = FunctionService()
    service.make_endpoint("quickstart", n_executors=2, workers_per_executor=2,
                          prefetch=2, policy="warm_affinity")

    # --- register a function (paper Listing 1 analogue) -------------------
    def preview_stats(doc):
        data = np.asarray(doc["data"])
        return {
            "name": doc["name"],
            "mean": float(data.mean()),
            "hot_pixels": int((data > doc["threshold"]).sum()),
        }

    fid = service.register_function(preview_stats, name="preview_stats",
                                    description="tomography preview stats")
    print(f"registered function: {fid[:16]}...")

    # --- invoke (paper Listing 2 analogue) ---------------------------------
    payload = {"name": "frame_000", "data": np.random.rand(256, 256),
               "threshold": 0.99}
    fut = service.run(fid, payload)                   # async -> TaskFuture
    print("status:", service.status(fut))
    print("result:", service.result(fut, timeout=10))
    print("latency breakdown (ms):",
          {k: round(v * 1e3, 3) for k, v in fut.latency_breakdown().items()})

    # --- memoization ---------------------------------------------------------
    t0 = time.monotonic()
    service.run(fid, payload, memoize=True).result(10)
    first = time.monotonic() - t0
    t0 = time.monotonic()
    memo_fut = service.run(fid, payload, memoize=True)
    memo_fut.result(10)
    repeat = time.monotonic() - t0
    print(f"memoization: first={first*1e3:.2f}ms repeat={repeat*1e3:.3f}ms "
          f"(state={memo_fut.state.value})")

    # --- user-driven batching ------------------------------------------------
    frames = [{"name": f"frame_{i:03d}", "data": np.random.rand(64, 64),
               "threshold": 0.99} for i in range(16)]
    outs = service.map(fid, frames, user_batched=False)
    print(f"batch of {len(outs)} frames processed; "
          f"hot pixels total = {sum(o['hot_pixels'] for o in outs)}")

    print("\nservice stats:", service.stats()["memo"])
    service.shutdown()


if __name__ == "__main__":
    main()
