"""Fabric-served inference: concurrent users streaming tokens through a
2-endpoint FaaS fabric (docs/serving.md; the §7 ML-inference case study
run through the fabric rather than beside it).

    PYTHONPATH=src python examples/serve_models.py [--users 6] [--tokens 12]

``serve_model`` registers prefill/decode-step/release as fabric functions
requiring the ``jit`` capability, so only the jit-capable endpoints receive
model work. Each user opens a session: the prompt prefills into a KV-cache
slot on whichever endpoint the forwarder picks, and every subsequent decode
step is routed back to that endpoint by session-sticky affinity
(``TaskEnvelope.session_id``) — moving would abandon the cache. Decode
steps from different users arriving at the same endpoint are merged by the
``DecodeCoalescer`` into one batched kernel invocation.

Midway through, the example kills one endpoint: the watchdog evicts its
session bindings, the affected sessions rebind to the survivor, re-prefill
from their token history (``serving.cache_migrations``), and keep
streaming — greedy decoding makes the migrated stream token-identical.

Expected output: a per-user token stream log with each user pinned to one
endpoint, a failover notice where half the users migrate, and a metrics
snapshot showing forwarder.session_hits covering the decode traffic,
serving.affinity_hits >> serving.cache_migrations, and fewer
serving.decode_batches than tokens generated (the continuous-batching win).
"""
import argparse
import threading

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core import FunctionService
from repro.core.containers import ContainerSpec
from repro.models.model import Model
from repro.serving.fabric import serve_model


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--users", type=int, default=6)
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_reduced("qwen1.5-0.5b").with_(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    service = FunctionService()
    jit_spec = ContainerSpec(
        name="jit", capabilities={"cpu", "jit"}, min_workers=0, max_workers=8
    )
    endpoints = [
        service.make_endpoint(f"site{i}", n_executors=1, containers=[jit_spec])
        for i in range(2)
    ]
    short = {e.endpoint_id: f"ep{i}" for i, e in enumerate(endpoints)}
    client = serve_model(
        service, model, params, name="qwen",
        max_len=8 + args.tokens + 4, max_sessions=args.users + 2,
    )

    print(f"-- {args.users} users x {args.tokens} tokens over "
          f"{len(endpoints)} endpoints --")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 8) for _ in range(args.users)]
    half = threading.Barrier(args.users + 1)  # +1: the chaos thread
    lock = threading.Lock()
    sessions = [None] * args.users

    def user(k: int) -> None:
        s = client.session(prompts[k])
        sessions[k] = s
        for j, tok in enumerate(s.stream(args.tokens)):
            if j == args.tokens // 2:
                half.wait()  # line everyone up for the mid-stream failover
            with lock:
                print(f"  user{k} [{short[s.endpoints[-1]]}] token {j}: {tok}")
        s.close()

    threads = [threading.Thread(target=user, args=(k,)) for k in range(args.users)]
    for t in threads:
        t.start()

    half.wait()
    victim = endpoints[0]
    print(f"\n-- killing {short[victim.endpoint_id]} mid-stream: its sessions "
          f"re-prefill on the survivor --")
    victim.kill()
    service.forwarder.check_endpoints()
    for t in threads:
        t.join()

    migrated = sum(1 for s in sessions if s.migrations)
    print(f"\n{migrated} session(s) migrated; per-user endpoints:")
    for k, s in enumerate(sessions):
        path = "->".join(dict.fromkeys(short[e] for e in s.endpoints))
        print(f"  user{k}: {path}  ttft={s.ttft_s * 1e3:.0f}ms "
              f"tokens={len(s.tokens)}")

    snap = service.metrics.snapshot()["counters"]
    print("\nfabric counters:")
    for name in ("forwarder.session_hits", "forwarder.session_evictions",
                 "serving.affinity_hits", "serving.cache_migrations",
                 "serving.prefills", "serving.tokens_generated",
                 "serving.decode_batches"):
        print(f"  {name}: {snap.get(name, 0)}")
    service.shutdown()


if __name__ == "__main__":
    main()
