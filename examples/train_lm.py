"""End-to-end training driver: train a ~100M-param LM through the FaaS
endpoint with prefetching + checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py                   # quick demo
    PYTHONPATH=src python examples/train_lm.py --full-100m --steps 300

The quick demo uses the reduced config; --full-100m builds a ~100M dense
model (the assignment's "train ~100M for a few hundred steps" driver —
expect ~hours on this 1-core CPU container; it is sized for a pod).
"""
import argparse

from repro.configs import get_reduced
from repro.core import FunctionService
from repro.models.model import Model
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/funcjax_train_ckpt")
    ap.add_argument("--full-100m", action="store_true")
    args = ap.parse_args()

    cfg = get_reduced("qwen1.5-0.5b").with_(dtype="float32")
    if args.full_100m:
        cfg = cfg.with_(n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
                        d_ff=2048, vocab=32768, name="dense-100m")
    model = Model(cfg)
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    service = FunctionService()
    service.make_endpoint("trainer", n_executors=1, workers_per_executor=1)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=max(args.steps // 10, 1),
                           total_steps=args.steps)
    tcfg = TrainConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                       ckpt_every=max(args.steps // 4, 1), ckpt_dir=args.ckpt,
                       prefetch_depth=2, log_every=max(args.steps // 8, 1))
    trainer = Trainer(model, ocfg, tcfg, service=service)
    if trainer.step:
        print(f"resumed from checkpoint at step {trainer.step}")
    history = trainer.run()
    print(f"loss: {history[0]['loss']:.4f} -> {history[-1]['loss']:.4f} "
          f"({len(history)} steps run; checkpoints in {args.ckpt})")
    service.shutdown()


if __name__ == "__main__":
    main()
