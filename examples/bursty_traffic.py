"""Bursty traffic against an elastic endpoint (paper §5.4 managed elasticity).

    PYTHONPATH=src python examples/bursty_traffic.py

Two bursts separated by a quiet period drive a policy-driven autoscaler: the
endpoint starts at ``min_blocks``, scales out in proportional steps while each
burst lasts (target-queue-depth policy: keep ≤2 queued+running tasks per
worker), then drains idle executors and scales back in to ``min_blocks`` once
the cool-down expires. No task is ever lost to a scale-in: a block is only
released after its executor is suspended and verified empty.

Expected output: a blocks-over-time trace climbing from 1 toward 5 during
each burst and returning to 1 in between, followed by the autoscaler's event
counts and the fabric-wide metrics snapshot (non-zero submit/complete
counters and latency percentiles from the shared MetricsRegistry).
"""
import time

from repro.core import FunctionService


def simulate_io(doc):
    time.sleep(doc.get("t", 0.0))  # simulated detector readout / IO
    return {"i": doc["i"]}


def blocks_of(ep) -> int:
    return sum(1 for e in ep._executor_list() if e.accepting())


def main() -> None:
    service = FunctionService()
    ep = service.make_endpoint(
        "bursty",
        n_executors=1,             # start small: min_blocks=1
        workers_per_executor=2,
        max_executors=5,           # provider ceiling (ProviderSpec.max_blocks)
        elastic=True,
        heartbeat_interval_s=0.05,  # autoscaler ticks at heartbeat cadence
        scale_cooldown_s=0.3,       # quiet period before any scale-in
        prefetch=2,
    )
    fid = service.register_function(simulate_io, name="simulate_io")

    t0 = time.monotonic()
    for burst in (1, 2):
        print(f"\n-- burst {burst}: 120 tasks x 20ms against "
              f"{blocks_of(ep)} block(s) --")
        futs = [service.run(fid, {"i": i, "t": 0.02}) for i in range(120)]
        while any(not f.done() for f in futs):
            print(f"   t+{time.monotonic()-t0:5.1f}s blocks={blocks_of(ep)} "
                  f"queue={ep.queue_depth()}")
            time.sleep(0.2)
        [f.result(30) for f in futs]
        print(f"   burst {burst} done at {blocks_of(ep)} blocks "
              f"(peak demand absorbed)")

        print("-- quiet: waiting for scale-in to min_blocks --")
        deadline = time.monotonic() + 20
        while blocks_of(ep) > 1 and time.monotonic() < deadline:
            time.sleep(0.1)
        print(f"   scaled in to {blocks_of(ep)} block(s)")

    scaler = ep.autoscaler.stats()
    print(f"\nautoscaler: policy={scaler['policy']} "
          f"scale_out_events={scaler['scale_out_events']} "
          f"scale_in_events={scaler['scale_in_events']} "
          f"blocks={scaler['blocks']} (min={scaler['min_blocks']}, "
          f"max={scaler['max_blocks']})")

    snap = service.metrics.snapshot()
    e2e = snap["histograms"]["service.e2e_latency_s"]
    print(f"metrics: submitted={snap['counters']['service.tasks_submitted']} "
          f"completed={snap['counters']['service.tasks_completed']} "
          f"e2e p50={e2e['p50']*1e3:.0f}ms p95={e2e['p95']*1e3:.0f}ms")
    service.shutdown()


if __name__ == "__main__":
    main()
