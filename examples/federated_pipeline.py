"""Federated pipeline: one fabric, many sites (funcX follow-up papers).

    PYTHONPATH=src python examples/federated_pipeline.py

A science workload fanned across three heterogeneous endpoints — a laptop,
a campus cluster, and a (simulated-WAN) supercomputer — through the central
Forwarder. Shows capacity-proportional map() sharding, latency-aware
routing, and failover when a whole site goes down mid-campaign.

Expected output: per-site routing shares (the big site taking the largest
map() shard, latency-aware routing shifting traffic off the slow WAN site),
then a mid-campaign site kill with every stranded task failed over — the
final tally shows all results delivered and a non-zero failover count.
"""
import time

import numpy as np

from repro.core import FunctionService


def analyze_frame(doc):
    time.sleep(doc.get("t", 0.0))  # simulated detector readout / IO
    data = np.asarray(doc["data"])
    return {"i": doc["i"], "mean": float(data.mean()),
            "hot": int((data > doc["threshold"]).sum())}


def main() -> None:
    service = FunctionService(policy="latency_aware")
    service.forwarder.liveness_threshold_s = 0.2
    service.forwarder.watchdog_interval_s = 0.02

    # three sites with very different capacity and "distance"
    laptop = service.make_endpoint("laptop", n_executors=1, workers_per_executor=2)
    service.make_endpoint("cluster", n_executors=2, workers_per_executor=4)
    service.make_endpoint("hpc", n_executors=4, workers_per_executor=4,
                          dispatch_interval_s=0.01)  # WAN RTT to the big site

    fid = service.register_function(analyze_frame, name="analyze_frame")
    print("fabric:", {eid: s["capacity"] for eid, s in
                      service.forwarder.stats()["endpoints"].items()})

    # --- capacity-proportional fan-out ------------------------------------
    frames = [{"i": i, "data": np.random.rand(128, 128), "threshold": 0.99}
              for i in range(60)]
    t0 = time.monotonic()
    outs = service.map(fid, frames, timeout=60)
    dt = time.monotonic() - t0
    print(f"campaign 1: {len(outs)} frames in {dt*1e3:.0f}ms "
          f"({len(outs)/dt:.0f} frames/s), hot pixels={sum(o['hot'] for o in outs)}")
    for eid, s in service.forwarder.stats()["endpoints"].items():
        print(f"  {eid}: routed={s['routed']} "
              f"ewma={None if s['latency_ewma_s'] is None else round(s['latency_ewma_s']*1e3, 2)}ms")

    # --- a whole site dies mid-campaign; the forwarder re-routes ----------
    # pin a slow slice of the campaign to the laptop, then pull its plug
    futs = [service.run(fid, dict(f, t=0.1),
                        endpoint_id=laptop.endpoint_id if f["i"] < 8 else None)
            for f in frames]
    time.sleep(0.03)
    laptop.kill()
    print("\nlaptop endpoint killed mid-campaign...")
    outs = [f.result(60) for f in futs]
    print(f"campaign 2: all {len(outs)} frames still completed "
          f"(failovers={service.forwarder.failovers})")

    # cluster keeps serving; dead site is excluded from routing
    outs = service.map(fid, frames[:10], timeout=60)
    assert len(outs) == 10
    fwd = service.forwarder.stats()
    print("dead endpoints:", [eid for eid, s in fwd["endpoints"].items() if s["dead"]])
    print("done — cluster + hpc absorbed the laptop's share.")
    service.shutdown()


if __name__ == "__main__":
    main()
