"""End-to-end serving driver (the paper's kind is a serving platform): serve
a small LM with continuously-batched requests through the FaaS endpoint.

    PYTHONPATH=src python examples/serve_llm.py [--arch qwen1.5-0.5b] [--requests 12]

Requests arrive as function invocations; the ServeEngine packs them into
shared-cache decode batches (user-driven batching made automatic), reports
time-to-first-token and per-token latency.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.models.model import Model
from repro.serving.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_reduced(args.arch).with_(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_batch=args.max_batch, max_len=96)
    print(f"serving {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.max_batch} continuous-batching slots")

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 12))
        reqs.append(engine.submit(prompt, max_new_tokens=args.max_new_tokens))

    t0 = time.monotonic()
    engine.run_until_drained(timeout=600)
    wall = time.monotonic() - t0

    ttfts = [(r.first_token_at - r.submitted) * 1e3 for r in reqs if r.first_token_at]
    total_tokens = sum(len(r.tokens) for r in reqs)
    print(f"completed {len(reqs)} requests / {total_tokens} tokens in {wall:.2f}s "
          f"({total_tokens/wall:.1f} tok/s aggregate)")
    print(f"time-to-first-token: mean {np.mean(ttfts):.1f}ms  p95 {np.percentile(ttfts, 95):.1f}ms")
    print(f"engine stats: {engine.stats()}")
    for r in reqs[:3]:
        print(f"  {r.request_id}: prompt[:4]={list(r.prompt[:4])} -> {r.tokens}")


if __name__ == "__main__":
    main()
