"""Event-driven science pipeline: data arrival triggers a DAG workflow.

    PYTHONPATH=src python examples/event_pipeline.py

The paper's core promise is computation that "can occur near data, be
triggered by events (e.g., arrival of new data)" (§1), and its §7 case
studies are all multi-step pipelines. Here a detector "writes" frames; each
:class:`DataArrivalEvent` on the :class:`EventBus` fires a :class:`Trigger`
that starts one run of a detect → (extract metadata ∥ extract spectrum) →
aggregate diamond workflow — the Skluma/DLHub pattern, but event-driven and
branching instead of a hand-rolled linear flow. Sibling branches are
submitted as ONE TaskBatch frame, and children follow their parent's warm
endpoint via affinity hints.

Expected output: one narrated line per arriving frame (hot-pixel count +
spectral peak), then a fabric summary showing `trigger.fired` == frames,
`workflow.runs{state=succeeded}` == frames, and 3 TaskBatch frames per
4-node graph (the two extract branches share a frame).
"""
import time

import numpy as np

from repro.core import (
    DataArrivalEvent,
    EventBus,
    FunctionService,
    Trigger,
    Workflow,
    WorkflowNode,
)

N_FRAMES = 6


def detect(doc):
    """Threshold the raw frame: which pixels fired?"""
    frame = np.asarray(doc["item"]["pixels"])
    return {"frame_id": doc["item"]["frame_id"],
            "pixels": frame,
            "hot": (frame > doc["item"]["threshold"])}


def extract_metadata(det):
    return {"frame_id": det["frame_id"], "n_hot": int(det["hot"].sum())}


def extract_spectrum(det):
    spectrum = np.abs(np.fft.rfft(det["pixels"].mean(axis=0)))
    return {"peak_bin": int(spectrum[1:].argmax()) + 1,
            "peak_power": float(spectrum[1:].max())}


def aggregate(upstream):
    meta, spec = upstream["metadata"], upstream["spectrum"]
    return {"frame_id": meta["frame_id"], "n_hot": meta["n_hot"],
            "peak_bin": spec["peak_bin"], "peak_power": spec["peak_power"]}


def main() -> None:
    service = FunctionService()
    service.make_endpoint("beamline", n_executors=2, workers_per_executor=4)

    wf = Workflow([
        WorkflowNode("detect", service.register_function(detect, name="detect")),
        WorkflowNode("metadata", service.register_function(extract_metadata),
                     deps=["detect"]),
        WorkflowNode("spectrum", service.register_function(extract_spectrum),
                     deps=["detect"]),
        WorkflowNode("aggregate", service.register_function(aggregate),
                     deps=["metadata", "spectrum"],
                     prepare=lambda doc, up: {"metadata": up["metadata"],
                                              "spectrum": up["spectrum"]}),
    ], name="frame-pipeline")

    bus = EventBus()
    trigger = bus.attach(Trigger(
        wf, service, name="frame-arrival",
        predicate=lambda e: e.source == "detector",
    ))

    rng = np.random.default_rng(7)
    print(f"detector streaming {N_FRAMES} frames onto the event bus...")
    for i in range(N_FRAMES):
        frame = rng.random((32, 64)) + np.sin(np.arange(64) * (i + 1) * 0.4)
        bus.publish(DataArrivalEvent(
            "detector",
            item={"frame_id": i, "pixels": frame, "threshold": 1.6},
        ))
        time.sleep(0.01)  # detector readout cadence

    for run in trigger.runs:
        out = run.wait(60)
        print(f"  frame {out['frame_id']}: {out['n_hot']:4d} hot pixels, "
              f"spectral peak @ bin {out['peak_bin']} "
              f"(power {out['peak_power']:.1f})")

    snap = service.metrics.snapshot()
    counters = snap["counters"]
    fwd = service.forwarder.stats()
    assert counters["trigger.fired{trigger=frame-arrival}"] == N_FRAMES
    assert counters["workflow.runs{state=succeeded}"] == N_FRAMES
    print(f"\nfabric: trigger.fired={counters['trigger.fired{trigger=frame-arrival}']} "
          f"workflow.runs(succeeded)={counters['workflow.runs{state=succeeded}']} "
          f"nodes={counters['workflow.nodes_completed']}")
    print(f"frames/graph: {fwd['batches_delivered'] / N_FRAMES:.1f} "
          f"(4 nodes in 3 TaskBatch frames — branches share one), "
          f"affinity_hits={counters.get('forwarder.affinity_hits', 0)}")
    print("done — every arrival event drove one DAG run end-to-end.")
    service.shutdown()


if __name__ == "__main__":
    main()
