"""Scientific case study (paper §7): metadata extraction + ML inference as an
automation flow — the Skluma/DLHub pattern on funcJAX.

    PYTHONPATH=src python examples/scientific_pipeline.py

A crawler "discovers" files; each file triggers a flow (Globus Automate
ActionProvider analogue): extract metadata -> run a reduced-LM featurizer ->
aggregate. Executor failure mid-run demonstrates the watchdog re-execution.
"""
import time

import numpy as np

from repro.core import ActionStep, Flow, FunctionService


def main() -> None:
    service = FunctionService()
    ep = service.make_endpoint("science", n_executors=2, workers_per_executor=2,
                               prefetch=4, heartbeat_interval_s=0.1, elastic=True)

    # -- step 1: metadata extraction (Skluma-style) -------------------------
    def extract_metadata(doc):
        data = np.asarray(doc["data"])
        return {
            "file": doc["file"],
            "rows": int(data.shape[0]),
            "mean": float(data.mean()),
            "histogram": np.histogram(data, bins=8)[0],
        }

    # -- step 2: ML inference (DLHub-style; reduced LM as the model) --------
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models.model import Model

    cfg = get_reduced("qwen2-0.5b").with_(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    fwd = jax.jit(lambda toks: model.forward(params, {"tokens": toks})[0])

    def ml_featurize(doc):
        # quantize the histogram into token ids, embed with the LM
        tokens = (np.asarray(doc["histogram"]) % cfg.vocab).astype(np.int32)[None]
        h = np.asarray(jax.block_until_ready(fwd(jnp.asarray(tokens))))
        return dict(doc, embedding_norm=float(np.linalg.norm(h)))

    # -- step 3: aggregate ----------------------------------------------------
    def classify(doc):
        label = "interesting" if doc["embedding_norm"] > 50 else "routine"
        return dict(doc, label=label)

    f_extract = service.register_function(extract_metadata, name="extract")
    f_ml = service.register_function(ml_featurize, name="ml_featurize")
    f_cls = service.register_function(classify, name="classify")

    flow = Flow([
        ActionStep(f_extract, name="extract"),
        ActionStep(f_ml, name="featurize"),
        ActionStep(f_cls, name="classify"),
    ], name="skluma-dlhub")

    rng = np.random.default_rng(0)
    files = [{"file": f"scan_{i:04d}.h5", "data": rng.standard_normal((64, 16))}
             for i in range(12)]

    t0 = time.monotonic()
    runs = [flow.start(service, f) for f in files]
    # inject a node failure mid-flight: the watchdog re-executes lost steps
    time.sleep(0.1)
    ep.kill_executor(0)
    results = [Flow.wait(r, timeout=120) for r in runs]
    dt = time.monotonic() - t0

    labels = [r["label"] for r in results]
    print(f"processed {len(results)} files in {dt:.2f}s "
          f"(through an executor failure; requeued={ep.requeued})")
    print("labels:", {l: labels.count(l) for l in set(labels)})
    per_step = [h["latency"]["t_e"] * 1e3 for r in runs for h in r.history]
    print(f"mean step execution time: {np.mean(per_step):.2f}ms over "
          f"{len(per_step)} flow steps")
    service.shutdown()


if __name__ == "__main__":
    main()
