"""Paper Fig. 7: task latency across an executor failure + recovery.

Two executors at capacity with a uniform stream of 30ms functions; one is
hard-killed 1s in (heartbeats stop, in-flight results vanish). The watchdog
requeues lost tasks and the elastic provider spawns a replacement. Reported:
pre-failure latency, the failure spike, and post-recovery latency."""
from __future__ import annotations

import time

from repro.core import FunctionService

from .common import emit, percentile, sleeper

TASK_S = 0.03
STREAM = 120


def run():
    rows = []
    svc = FunctionService()
    ep = svc.make_endpoint("fault", n_executors=2, workers_per_executor=1,
                           heartbeat_interval_s=0.1, elastic=True, max_executors=4)
    fid = svc.register_function(sleeper, name="sleep30ms")

    lats = [None] * STREAM
    start = time.monotonic()
    futs = []
    killed_at = None
    for i in range(STREAM):
        # uniform arrival at 2x single-worker capacity = at-capacity for 2
        target = start + i * TASK_S / 2
        now = time.monotonic()
        if target > now:
            time.sleep(target - now)
        if killed_at is None and time.monotonic() - start > 1.0:
            ep.kill_executor(0)
            killed_at = i
        t0 = time.monotonic()
        fut = svc.run(fid, {"i": i, "t": TASK_S})
        fut.add_done_callback(lambda f, i=i, t0=t0: lats.__setitem__(
            i, time.monotonic() - t0))
        futs.append(fut)
    for f in futs:
        f.result(60)

    pre = [l for l in lats[: killed_at - 5] if l is not None]
    spike_window = [l for l in lats[killed_at: killed_at + 30] if l is not None]
    post = [l for l in lats[-30:] if l is not None]
    rows.append(emit("fault/pre_failure_p50", percentile(pre, 50) * 1e6,
                     f"killed at task {killed_at}"))
    rows.append(emit("fault/failure_spike_max", max(spike_window) * 1e6,
                     "includes heartbeat detection + requeue"))
    rows.append(emit("fault/post_recovery_p50", percentile(post, 50) * 1e6,
                     f"replacement blocks: {len(ep.executors)}"))
    rows.append(emit("fault/tasks_requeued", float(ep.requeued),
                     "lost in-flight tasks re-executed"))
    assert all(l is not None for l in lats), "no task may be lost"
    svc.shutdown()
    return rows
