"""Paper Fig. 4 / Fig. 5 / Table 2: warm vs cold invocation latency and the
t_c / t_w / t_m / t_e breakdown. The paper's hello-world function, verbatim."""
from __future__ import annotations

import time

from repro.core import FunctionService

from .common import emit, scaled


def hello_world(event):
    return event


def run():
    rows = []
    svc = FunctionService()
    svc.make_endpoint("lat", n_executors=1, workers_per_executor=2, prefetch=2)
    fid = svc.register_function(hello_world, name="hello_world")

    # cold: the first invocation ever (executable build + routing caches)
    t0 = time.monotonic()
    svc.run(fid, "hello-world").result(30)
    cold = time.monotonic() - t0
    rows.append(emit("latency/cold_roundtrip", cold * 1e6, "first invocation"))

    # warm: steady state over 500 invocations
    lats, breakdown = [], {"t_c": 0.0, "t_w": 0.0, "t_m": 0.0, "t_e": 0.0}
    N = scaled(500, 50)
    for _ in range(N):
        t0 = time.monotonic()
        fut = svc.run(fid, "hello-world")
        fut.result(10)
        lats.append(time.monotonic() - t0)
        for k, v in fut.latency_breakdown().items():
            if k in breakdown:
                breakdown[k] += v
    warm = sum(lats) / N
    rows.append(emit("latency/warm_roundtrip", warm * 1e6,
                     f"n={N}; paper funcX warm=76ms (incl. 20.5ms WAN)"))
    for k in ("t_c", "t_w", "t_m", "t_e"):
        rows.append(emit(f"latency/breakdown_{k}", breakdown[k] / N * 1e6,
                         "Fig.5 decomposition"))
    rows.append(emit("latency/cold_warm_ratio", cold / warm * 100,
                     "x100; paper funcX cold/warm = 38x"))

    # jax-compiled function: cold = XLA compile, warm = executable-cache hit

    def compiled_fn(doc):
        return {"y": (doc["x"] @ doc["x"]).sum()}

    import numpy as np
    fid2 = svc.register_function(compiled_fn, name="compiled", jax_jit=True)
    payload = {"x": np.ones((256, 256), np.float32)}
    t0 = time.monotonic()
    svc.run(fid2, payload).result(60)
    cold2 = time.monotonic() - t0
    reps = scaled(50, 10)
    t0 = time.monotonic()
    for _ in range(reps):
        svc.run(fid2, payload).result(10)
    warm2 = (time.monotonic() - t0) / reps
    rows.append(emit("latency/jax_cold_compile", cold2 * 1e6, "trace+lower+XLA compile"))
    rows.append(emit("latency/jax_warm", warm2 * 1e6, "warm executable cache"))
    svc.shutdown()
    return rows
