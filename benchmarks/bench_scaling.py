"""Paper Fig. 6: strong scaling (fixed task count, growing workers) and weak
scaling (fixed tasks/worker). One CPU core caps real parallelism for busy
functions; no-op and sleep functions exercise the dispatch fabric exactly as
the paper's no-op/sleep tasks do."""
from __future__ import annotations

import time

from repro.core import FunctionService

from .common import emit, noop, sleeper

STRONG_TASKS = 512
WEAK_TASKS_PER_WORKER = 16
WORKER_COUNTS = (1, 2, 4, 8, 16, 32)


def _endpoint(svc, workers):
    # 4 workers per executor, like the paper's per-node worker pools
    n_exec = max(1, workers // 4)
    wpe = min(workers, 4)
    return svc.make_endpoint(f"scale-{workers}", n_executors=n_exec,
                             workers_per_executor=wpe, prefetch=4,
                             policy="least_loaded")


def run():
    rows = []
    for workers in WORKER_COUNTS:
        svc = FunctionService()
        _endpoint(svc, workers)
        fid = svc.register_function(noop, name="noop")
        futs = [svc.run(fid, {"i": i}) for i in range(STRONG_TASKS)]
        t0 = time.monotonic()
        # submission included in completion time, as in the paper
        for f in futs:
            f.result(120)
        dt = time.monotonic() - t0
        rows.append(emit(f"scaling/strong_noop_w{workers}",
                         dt / STRONG_TASKS * 1e6,
                         f"{STRONG_TASKS} tasks, {STRONG_TASKS/dt:.0f} req/s"))
        svc.shutdown()

    for workers in WORKER_COUNTS:
        svc = FunctionService()
        _endpoint(svc, workers)
        fid = svc.register_function(sleeper, name="sleep10ms")
        n = WEAK_TASKS_PER_WORKER * workers
        t0 = time.monotonic()
        futs = [svc.run(fid, {"i": i, "t": 0.01}) for i in range(n)]
        for f in futs:
            f.result(120)
        dt = time.monotonic() - t0
        # ideal weak scaling: flat completion time as workers grow
        rows.append(emit(f"scaling/weak_sleep10ms_w{workers}",
                         dt / n * 1e6, f"{n} tasks, completion {dt:.3f}s"))
        svc.shutdown()
    return rows
