"""Million-task scale tier: sharded fair-mode forwarder vs. the single-shard
degenerate case, plus multi-tenant fairness under a greedy flood.

Two experiments:

1. **throughput** — closed-loop no-op tasks through a 4-endpoint fair-mode
   fabric. Endpoints model funcX's remote dispatch: each delivered TaskBatch
   frame costs one network round-trip (a GIL-releasing sleep), then every
   task in it completes. In fair mode all routing and delivery serializes
   through the forwarder's pump thread, so a single Forwarder keeps at most
   one dispatch round-trip in flight; the ShardedForwarder's N per-shard
   pumps (each with its own lock, DRR drain, and delivery loop) overlap N.
   Full mode pushes ≥10^6 tasks through the sharded fabric and asserts ≥2x
   the single-shard rate, tracking tasks/s, sampled p99 sojourn, and peak
   RSS. The single-shard baseline runs 1/8 of the tasks (rates compare at
   steady state; nobody needs to wait 3 minutes for a known-slower config).
2. **fairness** — service-level and journaled: a light interactive tenant's
   closed-loop p99 alone vs. behind a greedy tenant's windowed flood, with
   per-tenant quota admission (greedy capped, rejections carry
   ``retry_after``) and weighted DRR (the light tenant's next task jumps the
   greedy backlog). Asserts the light tenant's mixed p99 stays within 2x of
   its solo p99 (full mode) and that the journal fold shows ZERO duplicate
   terminal commitments (``duplicate_completions == 0``) in every run.

Results land in ``benchmarks/results/million.json``.
"""
from __future__ import annotations

import json
import os
import resource
import tempfile
import threading
import time

from repro.core import (
    AdmissionError,
    FairnessPolicy,
    Forwarder,
    FunctionService,
    ShardedForwarder,
    TaskEnvelope,
    TaskFuture,
    TokenAuthority,
)
from repro.core.auth import (
    SCOPE_INVOKE,
    SCOPE_REGISTER_ENDPOINT,
    SCOPE_REGISTER_FUNCTION,
)

from .common import emit, percentile, scaled, sleeper, smoke_mode

N_TOTAL = scaled(1_000_000, 10_000)  # through the sharded fabric
N_SHARDS = 8
N_EPS = 4
DISPATCH_RTT_S = 0.012  # forwarder->endpoint frame round-trip (paper: WAN hop)
EP_CAPACITY = 64        # per-endpoint worker ceiling == frame size
N_THREADS = 8           # closed-loop submitter threads (2 per tenant)
WINDOW = 1024           # per-thread in-flight window
SAMPLE_EVERY = 8        # sojourn-latency sampling: every 8th window


class RemoteEndpoint:
    """A funcX-style remote endpoint as seen from the forwarder: delivering a
    TaskBatch frame costs one dispatch RTT (GIL released, like any socket
    write+read), after which the frame's no-op tasks complete."""

    def __init__(self, eid, capacity=EP_CAPACITY):
        self.endpoint_id = eid
        self._capacity = capacity

    def is_alive(self, max_heartbeat_age_s=None):
        return True

    def capacity(self):
        return self._capacity

    def has_warm(self, key):
        return False

    def submit_batch(self, frame):
        time.sleep(DISPATCH_RTT_S)
        for _env, fut in frame.pairs():
            fut.set_result(None)

    def submit(self, env, future):  # per-task fallback path
        time.sleep(DISPATCH_RTT_S)
        future.set_result(None)

    def shutdown(self):
        pass


# ---------------------------------------------------------------------------
# 1. throughput: single-shard vs sharded fair-mode fabric
# ---------------------------------------------------------------------------
def _run_fabric(make_fwd, n_tasks, n_threads=N_THREADS):
    fwd = make_fwd()
    for i in range(N_EPS):
        fwd.register(RemoteEndpoint(f"ep{i}"))
    per = n_tasks // n_threads
    barrier = threading.Barrier(n_threads + 1)
    lats = []  # sampled submit->complete sojourns, appended under the GIL

    def submitter(k):
        tenant = f"tenant{k % 4}"
        barrier.wait()
        for w, base in enumerate(range(0, per, WINDOW)):
            m = min(WINDOW, per - base)
            pairs = []
            for j in range(m):
                tid = f"m{k}-{base + j}"
                pairs.append(
                    (TaskEnvelope(task_id=tid, function_id="f", payload=b"",
                                  tenant=tenant),
                     TaskFuture(tid))
                )
            if w % SAMPLE_EVERY == 0:
                t0 = time.perf_counter()
                for _env, fut in pairs:
                    fut.add_done_callback(
                        lambda f, t0=t0: lats.append(time.perf_counter() - t0)
                    )
            fwd.submit_many(pairs)
            for _env, fut in pairs:
                fut.result(300)

    threads = [
        threading.Thread(target=submitter, args=(k,)) for k in range(n_threads)
    ]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.monotonic()
    for t in threads:
        t.join()
    dt = time.monotonic() - t0
    fwd.shutdown()
    n_done = n_threads * per
    return {
        "n_tasks": n_done,
        "tasks_per_s": n_done / dt,
        "p99_sojourn_ms": percentile(lats, 99) * 1e3,
        "wall_s": dt,
    }


def _throughput():
    fair = dict(max_batch=EP_CAPACITY, watchdog_interval_s=0.5)
    single = _run_fabric(
        lambda: Forwarder(fairness=FairnessPolicy(), **fair),
        max(N_TOTAL // N_SHARDS, 2_000),
    )
    sharded = _run_fabric(
        lambda: ShardedForwarder(
            n_shards=N_SHARDS, fairness=FairnessPolicy(), **fair
        ),
        N_TOTAL,
    )
    speedup = sharded["tasks_per_s"] / single["tasks_per_s"]
    peak_rss_mib = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
    if not smoke_mode():
        assert sharded["n_tasks"] >= 1_000_000, (
            f"full mode must push >=10^6 tasks, got {sharded['n_tasks']}"
        )
        assert speedup >= 2.0, (
            f"sharded fabric must sustain >=2x the single-shard rate: "
            f"{sharded['tasks_per_s']:.0f}/s vs {single['tasks_per_s']:.0f}/s "
            f"({speedup:.2f}x)"
        )
    return {
        "n_shards": N_SHARDS,
        "dispatch_rtt_s": DISPATCH_RTT_S,
        "single": single,
        "sharded": sharded,
        "speedup": speedup,
        "peak_rss_mib": peak_rss_mib,
    }


# ---------------------------------------------------------------------------
# 2. fairness: light tenant p99, solo vs behind a greedy flood
# ---------------------------------------------------------------------------
TASK_S = 0.005
GREEDY_QUOTA = 12    # < fabric capacity: admission keeps headroom for others
GREEDY_WINDOW = 36   # >> quota: every burst exercises admission rejection


def _make_fabric(authority, journal_dir):
    svc = FunctionService(
        authority=authority,
        fairness=FairnessPolicy(),
        n_shards=4,
        journal_dir=journal_dir,
    )
    ep_token = authority.issue("ops", scopes=(SCOPE_REGISTER_ENDPOINT,))
    for i in range(2):
        svc.make_endpoint(
            f"fair{i}", n_executors=1, workers_per_executor=12, token=ep_token
        )
    fid = svc.register_function(
        sleeper, name="million_sleeper", public=True,
        token=authority.issue("owner", scopes=(SCOPE_REGISTER_FUNCTION,)),
    )
    return svc, fid


def _light_loop(svc, fid, token, n, tag):
    """Closed-loop interactive tenant: one task at a time, per-task latency."""
    lats = []
    for i in range(n):
        t0 = time.perf_counter()
        svc.run(fid, {"i": i, "t": TASK_S, "tag": tag}, token=token).result(60)
        lats.append(time.perf_counter() - t0)
    return percentile(lats, 99) * 1e3


def _fairness(tmpdir, n_light):
    authority = TokenAuthority()
    # the greedy tenant's quota sits below fabric capacity (admission control
    # keeps headroom instead of letting one tenant saturate every worker);
    # the light tenant carries interactive weight so DRR serves its next
    # task ahead of the greedy backlog
    authority.set_tenant_profile("greedy", quota=GREEDY_QUOTA, weight=1.0)
    authority.set_tenant_profile("light", weight=4.0)
    light_token = authority.issue("light", scopes=(SCOPE_INVOKE,))
    greedy_token = authority.issue("greedy", scopes=(SCOPE_INVOKE,))

    svc, fid = _make_fabric(authority, os.path.join(tmpdir, "wal-solo"))
    solo_p99_ms = _light_loop(svc, fid, light_token, n_light, "solo")
    solo_dup = svc.journal.state().duplicate_completions
    svc.shutdown()

    svc, fid = _make_fabric(authority, os.path.join(tmpdir, "wal-mixed"))
    stop = threading.Event()
    stats = {"submitted": 0, "rejected": 0, "retry_after_ok": True}

    def greedy_flood():
        # bursts arrive as one batch: admission sees the whole window at once,
        # so everything beyond the quota rejects instead of sneaking in
        # between completions
        i = 0
        while not stop.is_set():
            futs = svc.batch_run(
                fid,
                [{"i": i + j, "t": TASK_S, "tag": "greedy"}
                 for j in range(GREEDY_WINDOW)],
                token=greedy_token,
            )
            i += GREEDY_WINDOW
            for f in futs:
                try:
                    f.result(60)
                    stats["submitted"] += 1
                except AdmissionError as exc:
                    stats["rejected"] += 1
                    if not (exc.retry_after > 0 and exc.tenant == "greedy"):
                        stats["retry_after_ok"] = False

    flood = threading.Thread(target=greedy_flood)
    flood.start()
    time.sleep(0.2)  # let the flood reach steady state before measuring
    try:
        mixed_p99_ms = _light_loop(svc, fid, light_token, n_light, "mixed")
    finally:
        stop.set()
        flood.join()
    mixed_dup = svc.journal.state().duplicate_completions
    svc.shutdown()

    assert solo_dup == 0 and mixed_dup == 0, (
        f"journal fold shows duplicate terminal commitments: "
        f"solo={solo_dup} mixed={mixed_dup}"
    )
    assert stats["rejected"] > 0 and stats["retry_after_ok"], (
        f"greedy windows beyond quota must reject with retry_after: {stats}"
    )
    slowdown = mixed_p99_ms / solo_p99_ms
    if not smoke_mode():
        assert slowdown <= 2.0, (
            f"greedy flood must not starve the light tenant: p99 "
            f"{mixed_p99_ms:.1f}ms mixed vs {solo_p99_ms:.1f}ms solo "
            f"({slowdown:.2f}x)"
        )
    return {
        "n_light": n_light,
        "task_s": TASK_S,
        "greedy_quota": GREEDY_QUOTA,
        "light_solo_p99_ms": solo_p99_ms,
        "light_mixed_p99_ms": mixed_p99_ms,
        "slowdown": slowdown,
        "greedy_completed": stats["submitted"],
        "greedy_rejected": stats["rejected"],
        "duplicate_completions": solo_dup + mixed_dup,
    }


def run():
    rows = []
    tput = _throughput()
    rows.append(emit(
        "million/single_shard_task", 1e6 / tput["single"]["tasks_per_s"],
        f"{tput['single']['tasks_per_s']:.0f} tasks/s, "
        f"p99 sojourn {tput['single']['p99_sojourn_ms']:.0f}ms",
    ))
    rows.append(emit(
        "million/sharded8_task", 1e6 / tput["sharded"]["tasks_per_s"],
        f"{tput['sharded']['tasks_per_s']:.0f} tasks/s over "
        f"{tput['sharded']['n_tasks']} tasks ({tput['speedup']:.2f}x single), "
        f"peak RSS {tput['peak_rss_mib']:.0f} MiB",
    ))

    n_light = scaled(300, 40)
    with tempfile.TemporaryDirectory(prefix="repro-million-") as tmpdir:
        fair = _fairness(tmpdir, n_light)
    rows.append(emit(
        "million/light_solo_p99", fair["light_solo_p99_ms"] * 1e3,
        "interactive tenant alone on the fabric",
    ))
    rows.append(emit(
        "million/light_mixed_p99", fair["light_mixed_p99_ms"] * 1e3,
        f"{fair['slowdown']:.2f}x solo behind greedy flood; "
        f"{fair['greedy_rejected']} rejections carried retry_after, "
        f"{fair['duplicate_completions']} duplicate commitments",
    ))

    out = os.path.join(os.path.dirname(__file__), "results", "million.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(
            {"smoke": smoke_mode(), "throughput": tput, "fairness": fair},
            f, indent=1,
        )
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny parameters for CI smoke runs")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        # re-evaluate module-level sizes chosen before the env var was set
        N_TOTAL = scaled(1_000_000, 10_000)
    print("name,us_per_call,derived")
    run()
