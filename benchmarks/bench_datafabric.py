"""Data fabric tier: DataRef indirection vs. inline payloads, predictive
routing, and ETA-overrun speculation.

Three experiments:

1. **throughput** — N tasks sharing one ≳1 MiB dataset, (a) inline through
   the Forwarder (every envelope carries the full packed array) vs. (b) as a
   :class:`DataRef` into a filesystem store (envelopes carry ~100 B; each
   endpoint fetches the blob once into its locality cache). The ref path
   must sustain ≥2x the inline throughput — the tentpole acceptance bar.
2. **eta_aware vs random** — a heterogeneous fabric (one wide fast endpoint,
   one narrow slow one). After a priming wave trains the runtime predictor,
   ``eta_aware`` must beat ``random`` on p99 task latency.
3. **speculation** — with a journaled fabric and backup-task speculation
   enabled against a pathologically slow endpoint: stragglers get backup
   copies, every task completes, and the journal fold must show ZERO
   duplicate terminal commitments (``duplicate_completions == 0``).

Results land in ``benchmarks/results/datafabric.json``.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.core import FileSystemStore, Forwarder, FunctionService

from .common import emit, percentile, scaled, sleeper, smoke_mode

DATASET_BYTES = 1 << 20  # ≥1 MiB payload: the acceptance-criterion regime


def reduce_doc(doc):
    # verifies the payload arrived intact (endpoints, not ends of a memcpy)
    # while staying O(1): the measured quantity is data movement, not compute
    x = doc["x"]
    return {"i": doc["i"], "n": int(x.shape[0]),
            "s": float(x[0]) + float(x[-1])}


def _gather(futs, timeout=120.0):
    return [f.result(timeout) for f in futs]


# ---------------------------------------------------------------------------
# 1. throughput: inline vs DataRef + filesystem store
# ---------------------------------------------------------------------------
def _throughput(tmpdir, n_tasks):
    dataset = np.arange(DATASET_BYTES // 4, dtype=np.float32)
    want = float(dataset[0]) + float(dataset[-1])
    n_want = dataset.shape[0]

    def run_mode(datastore):
        svc = FunctionService(datastore=datastore, spill_threshold=64 * 1024)
        svc.make_endpoint("io0", n_executors=2, workers_per_executor=2)
        fid = svc.register_function(reduce_doc, name="fabric_reduce")
        if datastore is not None:
            shared = svc.put_data(dataset)
            payloads = [{"x": shared, "i": i} for i in range(n_tasks)]
        else:
            payloads = [{"x": dataset, "i": i} for i in range(n_tasks)]
        t0 = time.monotonic()
        outs = _gather(svc.batch_run(fid, payloads))
        dt = time.monotonic() - t0
        assert all(o["s"] == want and o["n"] == n_want for o in outs)
        svc.shutdown()
        return n_tasks / dt

    # best-of-N per mode: the harness runs suites back to back in one
    # process, and a single measured window is at the mercy of whatever the
    # previous suite's teardown left draining — the ratio is about the data
    # path, not about transient scheduler noise
    trials = 3
    store = FileSystemStore(os.path.join(tmpdir, "blobs"))
    inline_tput = max(run_mode(None) for _ in range(trials))
    ref_tput = max(run_mode(store) for _ in range(trials))
    speedup = ref_tput / inline_tput
    assert speedup >= 2.0, (
        f"DataRef path must be >=2x inline for {DATASET_BYTES} B payloads: "
        f"{ref_tput:.1f}/s vs {inline_tput:.1f}/s ({speedup:.2f}x)"
    )
    return {
        "n_tasks": n_tasks,
        "payload_bytes": DATASET_BYTES,
        "inline_tasks_per_s": inline_tput,
        "dataref_tasks_per_s": ref_tput,
        "speedup": speedup,
    }


# ---------------------------------------------------------------------------
# 2. eta_aware vs random on a heterogeneous fabric
# ---------------------------------------------------------------------------
TASK_S = 0.02


def _hetero_fabric(policy, seed=7):
    fwd = Forwarder(policy=policy, seed=seed, watchdog_interval_s=0.02)
    svc = FunctionService(forwarder=fwd)
    svc.make_endpoint("wide", n_executors=1, workers_per_executor=8)
    svc.make_endpoint(
        "narrow", n_executors=1, workers_per_executor=1,
        dispatch_interval_s=0.02,
    )
    fid = svc.register_function(sleeper, name="fabric_sleeper")
    return svc, fid


def _policy_p99(policy, n_tasks):
    svc, fid = _hetero_fabric(policy)
    # priming wave: trains the runtime predictor (and latency EWMAs) so the
    # measured wave reflects steady-state routing, not exploration
    _gather(svc.batch_run(fid, [{"i": i, "t": TASK_S} for i in range(16)]))
    t0 = time.monotonic()
    done_at = {}
    futs = svc.batch_run(fid, [{"i": i, "t": TASK_S} for i in range(n_tasks)])
    for f in futs:
        f.add_done_callback(
            lambda fut: done_at.setdefault(fut.task_id, time.monotonic())
        )
    _gather(futs)
    lats = [done_at[f.task_id] - t0 for f in futs]
    svc.shutdown()
    return percentile(lats, 99)


def _eta_vs_random(n_tasks):
    random_p99 = _policy_p99("random", n_tasks)
    eta_p99 = _policy_p99("eta_aware", n_tasks)
    assert eta_p99 < random_p99, (
        f"eta_aware p99 {eta_p99 * 1e3:.1f}ms must beat "
        f"random p99 {random_p99 * 1e3:.1f}ms"
    )
    return {
        "n_tasks": n_tasks,
        "task_s": TASK_S,
        "random_p99_s": random_p99,
        "eta_aware_p99_s": eta_p99,
        "improvement": random_p99 / eta_p99,
    }


# ---------------------------------------------------------------------------
# 3. speculation: backups fire, exactly-once holds
# ---------------------------------------------------------------------------
def _speculation(tmpdir, n_tasks):
    fwd = Forwarder(
        policy="eta_aware",
        speculation=True,
        speculation_eta_factor=1.5,
        speculation_min_age_s=0.03,
        watchdog_interval_s=0.01,
    )
    svc = FunctionService(forwarder=fwd, journal_dir=os.path.join(tmpdir, "wal"))
    svc.make_endpoint("healthy", n_executors=1, workers_per_executor=4)
    # the straggler factory: one worker behind a long dispatch RTT — anything
    # routed here during exploration overruns its ETA bound
    svc.make_endpoint(
        "laggard", n_executors=1, workers_per_executor=1,
        dispatch_interval_s=0.15,
    )
    fid = svc.register_function(sleeper, name="fabric_spec_sleeper")
    futs = svc.batch_run(fid, [{"i": i, "t": TASK_S} for i in range(n_tasks)])
    outs = _gather(futs)
    assert sorted(o["i"] for o in outs) == list(range(n_tasks))
    time.sleep(0.25)  # let speculation losers drain into the dedupe path
    st = svc.journal.state()
    assert st.duplicate_completions == 0, (
        f"speculation produced {st.duplicate_completions} duplicate commitments"
    )
    backups = fwd.backups_launched
    dup_results = svc.metrics.counter("journal.duplicate_results").value
    svc.shutdown()
    return {
        "n_tasks": n_tasks,
        "backups_launched": backups,
        "duplicate_results": dup_results,
        "duplicate_completions": st.duplicate_completions,
    }


def run():
    rows = []
    n_io = scaled(40, 10)
    n_route = scaled(60, 24)
    n_spec = scaled(30, 12)
    with tempfile.TemporaryDirectory(prefix="repro-datafabric-") as tmpdir:
        tput = _throughput(tmpdir, n_io)
        rows.append(emit(
            "datafabric/inline_task", 1e6 / tput["inline_tasks_per_s"],
            f"{DATASET_BYTES} B inline through the Forwarder",
        ))
        rows.append(emit(
            "datafabric/dataref_task", 1e6 / tput["dataref_tasks_per_s"],
            f"speedup {tput['speedup']:.1f}x via fs store + locality cache",
        ))

        route = _eta_vs_random(n_route)
        rows.append(emit(
            "datafabric/random_p99", route["random_p99_s"] * 1e6,
            "heterogeneous fabric, random routing",
        ))
        rows.append(emit(
            "datafabric/eta_aware_p99", route["eta_aware_p99_s"] * 1e6,
            f"{route['improvement']:.1f}x better p99 than random",
        ))

        spec = _speculation(tmpdir, n_spec)
        rows.append(emit(
            "datafabric/speculation_backups", float(spec["backups_launched"]),
            f"{spec['duplicate_results']} deduped losers, "
            f"{spec['duplicate_completions']} duplicate commitments",
        ))

    out = os.path.join(os.path.dirname(__file__), "results", "datafabric.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(
            {
                "smoke": smoke_mode(),
                "throughput": tput,
                "routing": route,
                "speculation": spec,
            },
            f, indent=1,
        )
    return rows
