"""Paper Fig. 8: user-driven batching — average per-request latency vs batch
size, across functions of different durations (the five case-study scales),
including a real reduced-LM inference function (the DLHub analogue)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import FunctionService

from .common import emit

BATCH_SIZES = (1, 4, 16, 64)
N_REQ = 64


def _make_functions():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models.model import Model

    # the "ML inference" case study: a reduced qwen forward pass
    cfg = get_reduced("qwen2-0.5b").with_(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    fwd = jax.jit(lambda tokens: model.forward(params, {"tokens": tokens})[0])

    def lm_inference(doc):
        toks = jnp.asarray(doc["tokens"])
        if toks.ndim == 1:
            toks = toks[None]
        return {"h": np.asarray(jax.block_until_ready(fwd(toks)))[..., :4]}

    def sleep_1ms(doc):
        time.sleep(0.001)
        return doc

    def sleep_30ms(doc):
        time.sleep(0.03)
        return doc

    return {"sleep_1ms": sleep_1ms, "sleep_30ms": sleep_30ms,
            "lm_inference": lm_inference}


def run():
    rows = []
    fns = _make_functions()
    for name, fn in fns.items():
        svc = FunctionService()
        svc.make_endpoint("batch", n_executors=1, workers_per_executor=2, prefetch=2)
        meta = {"serialize_result": False, "pass_through": True} if name == "lm_inference" else {}
        fid = svc.register_function(fn, name=name, **meta)
        if name == "lm_inference":
            payloads = [{"tokens": np.random.default_rng(i).integers(
                0, 256, 16, dtype=np.int32)} for i in range(N_REQ)]
        else:
            payloads = [{"i": np.int64(i)} for i in range(N_REQ)]
        for bs in BATCH_SIZES:
            t0 = time.monotonic()
            futs = []
            for off in range(0, N_REQ, bs):
                chunk = payloads[off: off + bs]
                futs.extend(svc.batch_run(fid, chunk, user_batched=(bs > 1)))
            for f in futs:
                f.result(300)
            per_req = (time.monotonic() - t0) / N_REQ
            rows.append(emit(f"batching/{name}_bs{bs}", per_req * 1e6,
                             "user-driven batching (Fig. 8)"))
        svc.shutdown()
    return rows
