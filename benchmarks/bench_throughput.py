"""Paper §6.2.3: maximum sustained throughput (requests/second) through the
service + endpoint fabric (paper: 1694 and 1466 req/s on Theta and Cori),
plus the batched task-flow pipeline vs. the per-task path (§5.5, Fig. 8)."""
from __future__ import annotations

import time

from repro.core import Forwarder, FunctionService

from .common import emit, noop, scaled

N = scaled(3000, 200)
BATCH = 64  # TaskBatch frame size for the batched-vs-per-task comparison
# the pipeline comparison needs enough tasks for several full frames, or
# thread ramp-up noise dominates the smoke measurement
N_PIPE = scaled(3000, 768)


def _drain(futs):
    for f in futs:
        f.result(120)


def run():
    rows = []
    for policy in ("random", "least_loaded", "warm_affinity"):
        svc = FunctionService()
        svc.make_endpoint("tp", n_executors=2, workers_per_executor=4, prefetch=8,
                          policy=policy)
        fid = svc.register_function(noop, name="noop")
        t0 = time.monotonic()
        futs = [svc.run(fid, i) for i in range(N)]
        for f in futs:
            f.result(120)
        dt = time.monotonic() - t0
        rows.append(emit(f"throughput/{policy}", dt / N * 1e6,
                         f"{N/dt:.0f} req/s (paper: 1694 Theta / 1466 Cori)"))
        svc.shutdown()

    # batched task-flow pipeline vs. per-task submission (PR 2 tentpole):
    # identical no-op workload on one endpoint; batch_run() moves the tasks
    # as TaskBatch frames of BATCH through every tier, amortizing auth,
    # routing locks, dispatch rounds, and result-queue round-trips.
    svc = FunctionService(forwarder=Forwarder(max_batch=BATCH))
    svc.make_endpoint("cmp", n_executors=2, workers_per_executor=4, prefetch=8)
    fid = svc.register_function(noop, name="noop")
    _drain([svc.run(fid, i) for i in range(BATCH)])  # warm threads/executables
    dt_task, dt_batch = float("inf"), float("inf")
    for _ in range(3):  # best-of-3: damp scheduler noise on shared runners
        t0 = time.monotonic()
        _drain([svc.run(fid, i) for i in range(N_PIPE)])
        dt_task = min(dt_task, time.monotonic() - t0)
        t0 = time.monotonic()
        _drain(svc.batch_run(fid, list(range(N_PIPE))))
        dt_batch = min(dt_batch, time.monotonic() - t0)
    rows.append(emit("throughput/per_task", dt_task / N_PIPE * 1e6,
                     f"{N_PIPE/dt_task:.0f} req/s"))
    rows.append(emit(f"throughput/batched_b{BATCH}", dt_batch / N_PIPE * 1e6,
                     f"{N_PIPE/dt_batch:.0f} req/s {dt_task/dt_batch:.2f}x vs per-task"))
    svc.shutdown()

    # user-driven batching multiplies effective throughput (paper Fig. 8)
    import numpy as np

    svc = FunctionService()
    svc.make_endpoint("tpb", n_executors=2, workers_per_executor=4, prefetch=8)

    def vector_noop(doc):
        return doc

    fid = svc.register_function(vector_noop, name="vec_noop")
    payloads = [{"x": np.float32(i)} for i in range(N)]
    t0 = time.monotonic()
    futs = svc.batch_run(fid, payloads, user_batched=True)
    for f in futs:
        f.result(120)
    dt = time.monotonic() - t0
    rows.append(emit("throughput/user_batched", dt / N * 1e6,
                     f"{N/dt:.0f} req/s effective"))
    svc.shutdown()
    return rows
