"""Paper §6.2.3: maximum sustained throughput (requests/second) through the
service + endpoint fabric (paper: 1694 and 1466 req/s on Theta and Cori)."""
from __future__ import annotations

import time

from repro.core import FunctionService

from .common import emit, noop, scaled

N = scaled(3000, 200)


def run():
    rows = []
    for policy in ("random", "least_loaded", "warm_affinity"):
        svc = FunctionService()
        svc.make_endpoint("tp", n_executors=2, workers_per_executor=4, prefetch=8,
                          policy=policy)
        fid = svc.register_function(noop, name="noop")
        t0 = time.monotonic()
        futs = [svc.run(fid, i) for i in range(N)]
        for f in futs:
            f.result(120)
        dt = time.monotonic() - t0
        rows.append(emit(f"throughput/{policy}", dt / N * 1e6,
                         f"{N/dt:.0f} req/s (paper: 1694 Theta / 1466 Cori)"))
        svc.shutdown()

    # user-driven batching multiplies effective throughput (paper Fig. 8)
    import numpy as np

    svc = FunctionService()
    svc.make_endpoint("tpb", n_executors=2, workers_per_executor=4, prefetch=8)

    def vector_noop(doc):
        return doc

    fid = svc.register_function(vector_noop, name="vec_noop")
    payloads = [{"x": np.float32(i)} for i in range(N)]
    t0 = time.monotonic()
    futs = svc.batch_run(fid, payloads, user_batched=True)
    for f in futs:
        f.result(120)
    dt = time.monotonic() - t0
    rows.append(emit("throughput/user_batched", dt / N * 1e6,
                     f"{N/dt:.0f} req/s effective"))
    svc.shutdown()
    return rows
