"""Paper Table 3: completion time of a fixed workload vs % repeated requests.
(Paper: 403.8s -> 63.2s from 0% to 100% repeats on 100k one-second tasks;
here 400 x 20ms tasks, same sweep.)"""
from __future__ import annotations

import time

from repro.core import FunctionService

from .common import emit, sleeper

N = 400
TASK_S = 0.02


def run():
    rows = []
    for repeat_pct in (0, 25, 50, 75, 100):
        svc = FunctionService()
        svc.make_endpoint("memo", n_executors=1, workers_per_executor=4, prefetch=4)
        fid = svc.register_function(sleeper, name="sleep20ms")
        n_unique = max(1, int(N * (100 - repeat_pct) / 100))
        payloads = [{"i": i % n_unique, "t": TASK_S} for i in range(N)]
        t0 = time.monotonic()
        futs = [svc.run(fid, p, memoize=True) for p in payloads]
        for f in futs:
            f.result(120)
        dt = time.monotonic() - t0
        stats = svc.memo.stats()
        rows.append(emit(f"memoization/repeat_{repeat_pct}pct", dt / N * 1e6,
                         f"completion {dt:.2f}s, hit_rate {stats['hit_rate']:.2f}"))
        svc.shutdown()
    return rows
