"""Managed elasticity under bursty load (paper §5.4; funcX follow-ups).

An elastic endpoint starts at ``min_blocks``, absorbs a burst that demands
several times its capacity, and must (a) scale out in proportional steps
while the burst lasts and (b) scale back in to ``min_blocks`` once idle and
the cool-down expires. A sampler thread records blocks-over-time so the
bench JSON artifact captures the whole elasticity envelope, alongside the
burst's p50/p99 client-observed latency.

Rows:
    elasticity/burst            p50/p99 latency + peak blocks during the burst
    elasticity/scale_in         time from burst-drain to min_blocks
    elasticity/blocks_over_time the sampled `ms:blocks` trajectory
    elasticity/metrics          fabric counters from MetricsRegistry.snapshot()

Also writes ``benchmarks/results/elasticity.json`` (timeline + summary),
uploaded by CI's bench-smoke job.

Run standalone:  PYTHONPATH=src python -m benchmarks.bench_elasticity --smoke
(or directly:    python benchmarks/bench_elasticity.py --smoke)
"""
from __future__ import annotations

import json
import os
import threading
import time

if __package__ in (None, ""):  # direct-file run: python benchmarks/bench_elasticity.py
    import sys

    _here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, _here)
    sys.path.insert(0, os.path.join(os.path.dirname(_here), "src"))
    from common import emit, percentile, scaled, sleeper
else:
    from .common import emit, percentile, scaled, sleeper

from repro.core import FunctionService

N_BURST = scaled(400, 120)
TASK_S = 0.02
MIN_BLOCKS = 1
MAX_BLOCKS = 6
WORKERS_PER_BLOCK = 2
COOLDOWN_S = 0.3
SAMPLE_S = 0.02


class _BlockSampler(threading.Thread):
    """Samples the endpoint's accepting-block count on a fixed cadence."""

    def __init__(self, endpoint, period_s: float = SAMPLE_S):
        super().__init__(name="block-sampler", daemon=True)
        self.endpoint = endpoint
        self.period_s = period_s
        self.samples: list[tuple[float, int]] = []
        self._halt = threading.Event()  # NB: Thread owns a private _stop
        self._t0 = time.monotonic()

    def run(self) -> None:
        while not self._halt.is_set():
            blocks = sum(
                1 for e in self.endpoint._executor_list() if e.accepting()
            )
            self.samples.append((time.monotonic() - self._t0, blocks))
            self._halt.wait(self.period_s)

    def stop(self) -> None:
        self._halt.set()
        self.join(timeout=2.0)


def run():
    rows = []
    svc = FunctionService()
    ep = svc.make_endpoint(
        "elastic",
        n_executors=MIN_BLOCKS,
        workers_per_executor=WORKERS_PER_BLOCK,
        max_executors=MAX_BLOCKS,
        elastic=True,
        heartbeat_interval_s=0.05,
        scale_cooldown_s=COOLDOWN_S,
        prefetch=2,
    )
    fid = svc.register_function(sleeper, name="sleeper")

    sampler = _BlockSampler(ep)
    sampler.start()

    # -- burst: demand ~N*TASK_S seconds of work against 1 block ------------
    t0 = time.monotonic()
    futs = [svc.run(fid, {"i": i, "t": TASK_S}) for i in range(N_BURST)]
    lats = []
    for f in futs:
        f.result(120)
        ts = f.timestamps
        lats.append(ts.result_ready - ts.client_submit)
    burst_dt = time.monotonic() - t0

    # -- quiet: wait for scale-in back to min_blocks -------------------------
    t_drain = time.monotonic()
    deadline = t_drain + 30.0
    final_blocks = None
    while time.monotonic() < deadline:
        blocks = sum(1 for e in ep._executor_list() if e.accepting())
        if blocks <= MIN_BLOCKS:
            final_blocks = blocks
            break
        time.sleep(0.02)
    scale_in_s = time.monotonic() - t_drain
    sampler.stop()

    peak = max(b for _, b in sampler.samples)
    final = sampler.samples[-1][1] if final_blocks is None else final_blocks
    assert peak > MIN_BLOCKS, f"burst never scaled out (peak={peak})"
    assert final == MIN_BLOCKS, f"did not scale in to min_blocks (final={final})"

    snap = svc.metrics.snapshot()
    submitted = snap["counters"].get("service.tasks_submitted", 0)
    completed = snap["counters"].get("service.tasks_completed", 0)
    e2e = snap["histograms"].get("service.e2e_latency_s", {})
    assert submitted >= N_BURST and completed >= N_BURST and e2e.get("count", 0) > 0

    rows.append(emit(
        "elasticity/burst",
        burst_dt / N_BURST * 1e6,
        f"{N_BURST/burst_dt:.0f} req/s p50={percentile(lats, 50)*1e3:.1f}ms "
        f"p99={percentile(lats, 99)*1e3:.1f}ms peak_blocks={peak}",
    ))
    rows.append(emit(
        "elasticity/scale_in",
        scale_in_s * 1e6,
        f"blocks {peak}->{final} (min_blocks={MIN_BLOCKS}) in {scale_in_s:.2f}s "
        f"after cooldown={COOLDOWN_S}s",
    ))
    timeline = " ".join(f"{int(t*1000)}:{b}" for t, b in sampler.samples)
    rows.append(emit("elasticity/blocks_over_time", 0.0, timeline))
    rows.append(emit(
        "elasticity/metrics",
        0.0,
        f"submitted={submitted} completed={completed} "
        f"e2e_p95={e2e.get('p95')}s scale_out={ep.autoscaler.scale_out_events} "
        f"scale_in={ep.autoscaler.scale_in_events}",
    ))

    out = os.path.join(os.path.dirname(__file__), "results", "elasticity.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(
            {
                "burst_tasks": N_BURST,
                "task_s": TASK_S,
                "min_blocks": MIN_BLOCKS,
                "max_blocks": MAX_BLOCKS,
                "peak_blocks": peak,
                "final_blocks": final,
                "scale_in_s": round(scale_in_s, 3),
                "p50_ms": round(percentile(lats, 50) * 1e3, 2),
                "p99_ms": round(percentile(lats, 99) * 1e3, 2),
                "blocks_over_time": [
                    {"t_ms": int(t * 1000), "blocks": b} for t, b in sampler.samples
                ],
                "autoscaler": ep.autoscaler.stats(),
            },
            f,
            indent=1,
        )

    svc.shutdown()
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny parameters for CI smoke runs")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        # re-evaluate module-level sizes chosen before the env var was set
        N_BURST = scaled(400, 120)
    print("name,us_per_call,derived")
    run()
