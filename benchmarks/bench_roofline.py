"""Deliverable (g): roofline terms per (arch x shape x mesh) from the
multi-pod dry-run artifacts (benchmarks/results/dryrun.json). Emits one row
per live cell: the step-time lower bound and which term dominates."""
from __future__ import annotations

import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")


def run():
    rows = []
    if not os.path.exists(RESULTS):
        rows.append(emit("roofline/missing", 0.0,
                         "run: python -m repro.launch.dryrun --all --both-meshes"))
        return rows
    with open(RESULTS) as f:
        results = json.load(f)
    for key in sorted(results):
        rec = results[key]
        if rec.get("status") != "ok":
            continue
        ov = rec.get("overrides") or {}
        if ov:
            continue  # baseline rows only; hillclimb rows live in EXPERIMENTS.md
        a = rec["analysis"]
        r = a["roofline"]
        mesh = "512" if "multipod" in key else "256"
        name = f"roofline/{rec['arch']}_{rec['shape']}_{mesh}ch"
        bound = r["step_time_lower_bound_s"]
        rows.append(emit(
            name, bound * 1e6,
            f"bneck={r['bottleneck']} frac={r.get('roofline_fraction', 0):.3f} "
            f"fits={a['memory']['fits_hbm']} resident={a['memory']['resident_gib']}GiB",
        ))
    return rows
