"""Heterogeneous container fabric vs. homogeneous flat pools (paper §5.3–5.4,
§8 resource-aware scheduling).

A mixed cpu+jit workload runs twice over a two-endpoint fabric:

- **baseline** — the seed's homogeneous shape: identical endpoints, every
  worker interchangeable, jit functions registered with no requirements, so
  policy routing scatters each function's tasks across both endpoints and
  every endpoint pays its own cold compiles.
- **heterogeneous** — one cpu endpoint plus one endpoint hosting a typed
  ``jit`` container pool; jit functions carry ``ResourceSpec({"jit"})``, so
  capability-aware routing concentrates them on the capable endpoint and each
  function compiles exactly once.

The jit task stream arrives in rotated order per wave (how a real mixed
workload interleaves), which is exactly where homogeneous routing scatters
warm state. Asserts the acceptance criterion: capability-aware routing beats
the flat-pool baseline on warm-hit rate AND jit-task p50 latency.

Rows:
    heterogeneity/baseline        jit p50/p95 + warm-hit rate, flat pools
    heterogeneity/capability      jit p50/p95 + warm-hit rate, typed pools
    heterogeneity/speedup         p50 ratio + warm-rate delta

Also writes ``benchmarks/results/heterogeneity.json``, uploaded by CI's
bench-smoke job.

Run standalone:  PYTHONPATH=src python -m benchmarks.bench_heterogeneity --smoke
(or directly:    python benchmarks/bench_heterogeneity.py --smoke)
"""
from __future__ import annotations

import json
import os

if __package__ in (None, ""):  # direct-file run
    import sys

    _here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, _here)
    sys.path.insert(0, os.path.join(os.path.dirname(_here), "src"))
    from common import emit, percentile, scaled
else:
    from .common import emit, percentile, scaled

from repro.core import (
    ContainerSpec,
    FunctionService,
    Invocation,
    ResourceSpec,
    default_container_spec,
)

N_WAVES = 3   # tasks per function, submitted in rotated waves
WORKERS = 2


BOOT_S = 0.05  # simulated per-executor container instantiation (Table 4)


def _make_jit_fns(n):
    """n jit-compiled function variants with distinct closed-over constants
    (distinct function ids, so each pays its own compile + container boot).
    The deterministic ``container_boot_s`` dominates the cold cost because
    XLA's in-process cache makes re-compiles of identical HLO nearly free —
    without it the benchmark would measure scheduler noise, not warm-state
    locality."""
    fns = []
    for k in range(n):
        def fn(doc, _k=float(k + 1)):
            import jax.numpy as jnp

            x = doc["x"]
            for _ in range(8):  # enough graph for a non-trivial compile
                x = jnp.sin(x) * _k + jnp.cos(x @ x)
            return {"y": x}

        fns.append(fn)
    return fns


def _cpu_fn(doc):
    return {"i": doc.get("i", 0)}


def _run_config(heterogeneous: bool, n_jit_fns: int, n_cpu_per_wave: int):
    """One full mixed workload; returns (jit_latencies, warm_hits, cold_starts)."""
    import numpy as np

    svc = FunctionService()
    if heterogeneous:
        svc.make_endpoint("cpu-site", n_executors=1, workers_per_executor=WORKERS)
        svc.make_endpoint(
            "accel-site", n_executors=1,
            containers=[
                default_container_spec(WORKERS),
                ContainerSpec("jit", frozenset({"cpu", "jit"}),
                              min_workers=0, max_workers=WORKERS),
            ],
        )
        requirements = ResourceSpec(frozenset({"jit"}), preferred_container="jit")
    else:
        svc.make_endpoint("site-a", n_executors=1, workers_per_executor=WORKERS)
        svc.make_endpoint("site-b", n_executors=1, workers_per_executor=WORKERS)
        requirements = None

    cpu_fid = svc.register_function(_cpu_fn, name="cpu_fn")
    jit_fids = [
        svc.register_function(fn, name=f"jit_fn{k}", static=k, jax_jit=True,
                              container_boot_s=BOOT_S, requirements=requirements)
        for k, fn in enumerate(_make_jit_fns(n_jit_fns))
    ]

    x = np.eye(4, dtype=np.float32)
    jit_lats = []
    for wave in range(N_WAVES):
        # rotate the submission order per wave: a mixed stream's arrival
        # order is arbitrary, and rotation is what scatters (function ->
        # endpoint) placement under homogeneous policy routing
        order = jit_fids[wave % n_jit_fns:] + jit_fids[: wave % n_jit_fns]
        invocations = [Invocation(function_id=fid, payload={"x": x}) for fid in order]
        invocations += [
            Invocation(function_id=cpu_fid, payload={"i": i})
            for i in range(n_cpu_per_wave)
        ]
        futs = svc.run_many(invocations)
        for f in futs:
            f.result(120)
        for f in futs[: len(order)]:
            ts = f.timestamps
            jit_lats.append(ts.result_ready - ts.client_submit)

    snap = svc.metrics.snapshot()
    warm = snap["counters"].get("warming.warm_hits", 0)
    cold = snap["counters"].get("warming.cold_starts", 0)
    svc.shutdown()
    return jit_lats, warm, cold


def run():
    n_jit_fns = scaled(6, 3)       # distinct jit functions (distinct compiles)
    n_cpu_per_wave = scaled(8, 4)  # cpu filler tasks interleaved per wave
    rows = []
    results = {}
    for label, het in (("baseline", False), ("capability", True)):
        lats, warm, cold = _run_config(het, n_jit_fns, n_cpu_per_wave)
        rate = warm / max(1, warm + cold)
        p50, p95 = percentile(lats, 50), percentile(lats, 95)
        results[label] = {
            "jit_p50_ms": round(p50 * 1e3, 2),
            "jit_p95_ms": round(p95 * 1e3, 2),
            "warm_hits": warm,
            "cold_starts": cold,
            "warm_hit_rate": round(rate, 4),
        }
        rows.append(emit(
            f"heterogeneity/{label}",
            p50 * 1e6,
            f"jit p50={p50*1e3:.1f}ms p95={p95*1e3:.1f}ms "
            f"warm={warm} cold={cold} rate={rate:.2f}",
        ))

    base, het = results["baseline"], results["capability"]
    # acceptance: capability-aware routing beats the homogeneous flat-pool
    # baseline on warm-hit rate and p50 latency for the mixed workload
    assert het["warm_hit_rate"] > base["warm_hit_rate"], (
        f"warm-hit rate did not improve: {het['warm_hit_rate']} "
        f"<= {base['warm_hit_rate']}"
    )
    assert het["jit_p50_ms"] < base["jit_p50_ms"], (
        f"jit p50 did not improve: {het['jit_p50_ms']}ms "
        f">= {base['jit_p50_ms']}ms"
    )
    speedup = base["jit_p50_ms"] / max(1e-9, het["jit_p50_ms"])
    rows.append(emit(
        "heterogeneity/speedup",
        0.0,
        f"p50 {base['jit_p50_ms']}ms->{het['jit_p50_ms']}ms ({speedup:.1f}x) "
        f"warm rate {base['warm_hit_rate']:.2f}->{het['warm_hit_rate']:.2f}",
    ))

    out = os.path.join(os.path.dirname(__file__), "results", "heterogeneity.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(
            {
                "jit_functions": n_jit_fns,
                "waves": N_WAVES,
                "cpu_tasks_per_wave": n_cpu_per_wave,
                "workers_per_endpoint": WORKERS,
                "p50_speedup": round(speedup, 2),
                **{k: v for k, v in results.items()},
            },
            f,
            indent=1,
        )
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    run()
