"""Federated fabric: throughput and p50/p95 latency as endpoints scale.

The follow-up funcX papers make the Forwarder the unit of federation; this
suite measures what that tier buys: aggregate throughput and tail latency for
a worker-bound task at 1, 2, and 4 endpoints under each endpoint-routing
policy, plus a heterogeneous-fabric case where ``latency_aware`` routing must
learn to avoid a slow (high simulated RTT) endpoint.
"""
from __future__ import annotations

import time

from repro.core import FunctionService

from .common import emit, percentile, scaled, sleeper

N = scaled(300, 100)
TASK_S = 0.02  # worker-bound: fabric capacity, not submit overhead, dominates
POLICIES = ("random", "least_outstanding", "latency_aware", "warm_affinity")
ENDPOINT_COUNTS = (1, 2, 4)


def _drive(svc: FunctionService, fid: str, n: int):
    # warm-up: let endpoint/executor/worker threads finish spinning up and
    # executables warm so the timed window measures steady-state routing
    warm = [svc.run(fid, {"i": -1, "t": 0.0}) for _ in range(16)]
    for f in warm:
        f.result(30)
    t0 = time.monotonic()
    futs = [svc.run(fid, {"i": i, "t": TASK_S}) for i in range(n)]
    lats = []
    for f in futs:
        f.result(120)
        ts = f.timestamps
        lats.append(ts.result_ready - ts.client_submit)
    return time.monotonic() - t0, lats


def run():
    rows = []
    for policy in POLICIES:
        for n_eps in ENDPOINT_COUNTS:
            svc = FunctionService(policy=policy)
            for i in range(n_eps):
                svc.make_endpoint(f"fed{i}", n_executors=2, workers_per_executor=4,
                                  prefetch=2)
            fid = svc.register_function(sleeper, name="sleeper")
            dt, lats = _drive(svc, fid, N)
            rows.append(emit(
                f"federation/{policy}/ep{n_eps}",
                dt / N * 1e6,
                f"{N/dt:.0f} req/s p50={percentile(lats, 50)*1e3:.1f}ms "
                f"p95={percentile(lats, 95)*1e3:.1f}ms",
            ))
            svc.shutdown()

    # batched vs per-task across the fabric: the same worker-bound fan-out
    # submitted as independent run() calls vs. one capacity-sharded batch per
    # endpoint (TaskBatch frames through forwarder -> endpoint -> executor)
    from repro.core import Forwarder

    for n_eps in (1, 2):
        svc = FunctionService(forwarder=Forwarder(max_batch=64))
        for i in range(n_eps):
            svc.make_endpoint(f"fb{i}", n_executors=2, workers_per_executor=4,
                              prefetch=2)
        fid = svc.register_function(sleeper, name="sleeper")
        warm = [svc.run(fid, {"i": -1, "t": 0.0}) for _ in range(16)]
        for f in warm:
            f.result(30)
        t0 = time.monotonic()
        futs = [svc.run(fid, {"i": i, "t": 0.0}) for i in range(N)]
        for f in futs:
            f.result(120)
        dt_task = time.monotonic() - t0
        t0 = time.monotonic()
        outs = svc.map(fid, [{"i": i, "t": 0.0} for i in range(N)], timeout=120)
        dt_batch = time.monotonic() - t0
        assert len(outs) == N
        rows.append(emit(
            f"federation/batched_vs_per_task/ep{n_eps}",
            dt_batch / N * 1e6,
            f"batched {N/dt_batch:.0f} req/s vs per-task {N/dt_task:.0f} req/s "
            f"({dt_task/dt_batch:.2f}x)",
        ))
        svc.shutdown()

    # heterogeneous fabric: one endpoint simulates a 20ms WAN RTT dispatch
    # cadence; latency_aware should learn to send traffic to the fast site
    for policy in ("random", "latency_aware"):
        svc = FunctionService(policy=policy)
        svc.make_endpoint("near", n_executors=2, workers_per_executor=4, prefetch=2)
        svc.make_endpoint("far", n_executors=2, workers_per_executor=4, prefetch=2,
                          dispatch_interval_s=0.02)
        fid = svc.register_function(sleeper, name="sleeper")
        n = max(N // 2, 50)
        dt, lats = _drive(svc, fid, n)
        fwd = svc.forwarder.stats()["endpoints"]
        near_share = max(
            (s["routed"] for s in fwd.values()), default=0
        ) / max(1, sum(s["routed"] for s in fwd.values()))
        rows.append(emit(
            f"federation/hetero_{policy}",
            dt / n * 1e6,
            f"{n/dt:.0f} req/s p95={percentile(lats, 95)*1e3:.1f}ms "
            f"hot-endpoint share={near_share:.2f}",
        ))
        svc.shutdown()
    return rows
