"""Paper Fig. 9: completion time of a burst of short tasks vs the executor
prefetch count (paper: benefit saturates near workers-per-node).

The manager->executor round trip is simulated with tick_s=5ms (the paper's
endpoints sit across a WAN from the service; in-process dispatch would hide
the effect prefetching exists to amortize). Without prefetch each round moves
at most idle-worker tasks; with it, idle+prefetch."""
from __future__ import annotations

import time

from repro.core import FunctionService

from .common import emit, sleeper

N = 200
TASK_S = 0.001
RTT_S = 0.005


def run():
    rows = []
    for prefetch in (0, 1, 2, 4, 8, 16):
        svc = FunctionService()
        svc.make_endpoint("pf", n_executors=1, workers_per_executor=4,
                          prefetch=prefetch, dispatch_interval_s=RTT_S)
        fid = svc.register_function(sleeper, name="sleep1ms")
        t0 = time.monotonic()
        futs = [svc.run(fid, {"i": i, "t": TASK_S}) for i in range(N)]
        for f in futs:
            f.result(120)
        dt = time.monotonic() - t0
        rows.append(emit(f"prefetch/count_{prefetch}", dt / N * 1e6,
                         f"completion {dt:.3f}s @5ms RTT (Fig. 9)"))
        svc.shutdown()
    return rows
