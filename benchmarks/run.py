"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only latency,scaling,...]

Emits ``name,us_per_call,derived`` CSV rows (also captured in
benchmarks/results/bench.json).
"""
from __future__ import annotations

import argparse
import json
import os
import time

SUITES = (
    "latency",        # Fig. 4/5, Table 2
    "scaling",        # Fig. 6 strong + weak
    "throughput",     # §6.2.3
    "federation",     # multi-endpoint fabric: policies x endpoint counts
    "heterogeneity",  # §5.3-5.4/§8: typed container pools + capability routing
    "elasticity",     # §5.4 managed elasticity: blocks-over-time under burst
    "workflow",       # §7 pipelines: diamond DAG vs. linear Flow
    "fault",          # Fig. 7
    "chaos",          # durability tier: faults + full fabric restart, exactly-once
    "datafabric",     # data tier: DataRef vs inline, eta_aware routing, speculation
    "million",        # scale tier: sharded fair-mode forwarder + tenant fairness
    "serving",        # serving tier: KV-affinity routing + continuous batching
    "memoization",    # Table 3
    "warming",        # Table 4 (container instantiation analogue)
    "batching",       # Fig. 8
    "prefetch",       # Fig. 9
    "roofline",       # deliverable (g), from the dry-run artifacts
)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", help="comma-separated subset of suites")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny parameters for CI smoke runs")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    selected = args.only.split(",") if args.only else list(SUITES)

    print("name,us_per_call,derived")
    all_rows = []
    t_start = time.monotonic()
    for suite in selected:
        mod = __import__(f"benchmarks.bench_{suite}", fromlist=["run"])
        t0 = time.monotonic()
        rows = mod.run()
        all_rows.extend(rows)
        print(f"# suite {suite}: {len(rows)} rows in {time.monotonic()-t0:.1f}s",
              flush=True)
    print(f"# total: {len(all_rows)} rows in {time.monotonic()-t_start:.1f}s")

    out = os.path.join(os.path.dirname(__file__), "results", "bench.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(all_rows, f, indent=1)


if __name__ == "__main__":
    main()
