"""Paper Table 4: cold 'container' instantiation vs warm reuse. On the TPU
adaptation a container is a compiled executable: cold = trace+lower+XLA
compile (+ weight residency), warm = executable-cache hit. Swept over
function sizes the way the paper sweeps container technologies."""
from __future__ import annotations

import time

import numpy as np

from repro.core import FunctionService

from .common import emit


def _funcs():
    import jax
    import jax.numpy as jnp

    def small(doc):  # elementwise
        return {"y": jnp.tanh(doc["x"]) * 2}

    def medium(doc):  # one matmul
        return {"y": (doc["x"] @ doc["x"]).sum()}

    from repro.configs import get_reduced
    from repro.models.model import Model

    cfg = get_reduced("qwen1.5-0.5b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def lm_step(doc):  # a whole reduced-LM loss
        return {"loss": model.loss(params, {"tokens": jnp.asarray(doc["tokens"])})[0]}

    return {
        "small_elementwise": (small, {"x": np.ones((64, 64), np.float32)}),
        "medium_matmul": (medium, {"x": np.ones((512, 512), np.float32)}),
        "reduced_lm_loss": (lm_step, {"tokens": np.ones((2, 32), np.int32)}),
    }


def run():
    rows = []
    for name, (fn, payload) in _funcs().items():
        svc = FunctionService()
        svc.make_endpoint("warm", n_executors=1, workers_per_executor=1)
        fid = svc.register_function(fn, name=name, jax_jit=True)
        t0 = time.monotonic()
        svc.run(fid, payload).result(120)
        cold = time.monotonic() - t0
        t0 = time.monotonic()
        for _ in range(20):
            svc.run(fid, payload).result(30)
        warm = (time.monotonic() - t0) / 20
        rows.append(emit(f"warming/{name}_cold", cold * 1e6,
                         "XLA compile = container boot (Table 4)"))
        rows.append(emit(f"warming/{name}_warm", warm * 1e6,
                         f"cold/warm = {cold/warm:.0f}x"))
        svc.shutdown()
    return rows
