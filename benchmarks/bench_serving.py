"""Serving tier: fabric-served inference with KV-affinity routing and
endpoint-level continuous batching (the DLHub/ML-inference case study of §7
run *through* the fabric instead of beside it).

One experiment, two configurations over the same journaled 2-endpoint
fabric and the same reduced model:

1. **batched** — ``serve_model(batching=True)``: concurrent decode tasks
   arriving at an endpoint are merged by the ``DecodeCoalescer`` into one
   batched kernel invocation against the shared stacked KV cache.
2. **unbatched** — ``batching=False``: every decode task runs its own
   batch-1 kernel (the per-request baseline a naive FaaS deployment gets).

Both phases drive ``N_SESSIONS`` concurrent closed-loop users, each
streaming ``N_NEW`` greedy tokens. Session-sticky routing keeps every
decode step on the endpoint holding the session's cache slot, so
``serving.affinity_hits`` must cover all decode steps and the journal fold
must show zero duplicate terminal commitments. Full mode asserts the
batched configuration reaches >=2x the unbatched aggregate tokens/s.

Results land in ``benchmarks/results/serving.json``.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core import FunctionService
from repro.core.containers import ContainerSpec
from repro.models.model import Model
from repro.serving.fabric import reset_serving, serve_model

from .common import emit, percentile, scaled, smoke_mode

N_SESSIONS = scaled(16, 4)   # concurrent users (acceptance floor: 16)
N_NEW = scaled(24, 6)        # greedy tokens streamed per user
PROMPT_LEN = 8               # fixed: one prefill compile per phase
N_ENDPOINTS = 2


def _phase(model, params, batching: bool, journal_dir: str) -> dict:
    svc = FunctionService(journal_dir=journal_dir)
    spec = ContainerSpec(
        name="jit", capabilities={"cpu", "jit"},
        min_workers=0, max_workers=N_SESSIONS,
    )
    eps = [
        svc.make_endpoint(f"site{i}", n_executors=1, containers=[spec])
        for i in range(N_ENDPOINTS)
    ]
    client = serve_model(
        svc, model, params,
        name="qwen-batched" if batching else "qwen-sequential",
        max_len=PROMPT_LEN + N_NEW + 4,
        max_sessions=N_SESSIONS + N_ENDPOINTS,
        batching=batching,
        window_s=0.010,
    )
    rng = np.random.default_rng(0)
    # warm both endpoints (prefill + decode jit compiles) outside the clock
    for ep in eps:
        with client.session(
            rng.integers(0, model.cfg.vocab, PROMPT_LEN),
            endpoint_id=ep.endpoint_id,
        ) as s:
            for _ in s.stream(2):
                pass

    prompts = [
        rng.integers(0, model.cfg.vocab, PROMPT_LEN) for _ in range(N_SESSIONS)
    ]
    ttfts: list = [None] * N_SESSIONS
    counts = [0] * N_SESSIONS

    def user(k: int) -> None:
        s = client.session(prompts[k])
        for _ in s.stream(N_NEW):
            pass
        ttfts[k] = s.ttft_s
        counts[k] = len(s.tokens)
        s.close()

    threads = [threading.Thread(target=user, args=(k,)) for k in range(N_SESSIONS)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.monotonic() - t0

    snap = svc.metrics.snapshot()
    counters = snap["counters"]
    merge_h = snap["histograms"].get("serving.merged_per_step")
    mean_merge = (
        round(merge_h["sum"] / merge_h["count"], 2)
        if merge_h and merge_h["count"] else None
    )
    dup = svc.journal.state().duplicate_completions
    out = {
        "batching": batching,
        "sessions": N_SESSIONS,
        "tokens": int(sum(counts)),
        "wall_s": round(wall, 3),
        "tokens_per_s": round(sum(counts) / wall, 1),
        "ttft_p99_s": round(percentile([t for t in ttfts if t], 99), 4),
        "affinity_hits": counters.get("serving.affinity_hits", 0),
        "cache_migrations": counters.get("serving.cache_migrations", 0),
        "decode_batches": counters.get("serving.decode_batches", 0),
        "mean_merge": mean_merge,
        "duplicate_completions": dup,
    }
    svc.shutdown()
    reset_serving()
    assert out["affinity_hits"] > 0, "decode steps never hit a resident cache"
    assert dup == 0, f"journal fold shows {dup} duplicate terminal commitments"
    return out


def run():
    # Sized so one decode step (~40 ms) dwarfs the fabric round-trip
    # (~1.4 ms): the compute-dominated regime real model serving lives in,
    # where a wide batched step costs *less* wall time than a batch-1 step
    # repeated (memory-bound weights, better core utilization). The
    # repo-default reduced config decodes in 0.14 ms — there batching has
    # nothing to amortize and the coalescer window would only add sync.
    cfg = get_reduced("qwen1.5-0.5b").with_(
        dtype="float32", d_model=768, n_layers=10, n_heads=12,
    )
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows = []

    with tempfile.TemporaryDirectory(prefix="repro-serving-") as tmpdir:
        seq = _phase(model, params, batching=False,
                     journal_dir=os.path.join(tmpdir, "seq"))
        bat = _phase(model, params, batching=True,
                     journal_dir=os.path.join(tmpdir, "bat"))

    speedup = bat["tokens_per_s"] / max(seq["tokens_per_s"], 1e-9)
    rows.append(emit(
        "serving/unbatched_token_us", 1e6 / max(seq["tokens_per_s"], 1e-9),
        f"{seq['tokens_per_s']:.0f} tok/s, p99 TTFT {seq['ttft_p99_s'] * 1e3:.0f} ms "
        f"({N_SESSIONS} sessions, batch-1 kernels)",
    ))
    rows.append(emit(
        "serving/batched_token_us", 1e6 / max(bat["tokens_per_s"], 1e-9),
        f"{bat['tokens_per_s']:.0f} tok/s, p99 TTFT {bat['ttft_p99_s'] * 1e3:.0f} ms, "
        f"{speedup:.2f}x unbatched; mean merge {bat['mean_merge']}, "
        f"{bat['affinity_hits']} affinity hits, "
        f"{bat['duplicate_completions']} duplicate commitments",
    ))
    if not smoke_mode():
        assert speedup >= 2.0, (
            f"continuous batching must reach 2x the per-request baseline at "
            f"{N_SESSIONS} sessions; measured {speedup:.2f}x"
        )

    out = os.path.join(os.path.dirname(__file__), "results", "serving.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(
            {"smoke": smoke_mode(), "unbatched": seq, "batched": bat,
             "speedup": round(speedup, 2)},
            f, indent=1,
        )
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny parameters for CI smoke runs")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        N_SESSIONS = scaled(16, 4)
        N_NEW = scaled(24, 6)
    print("name,us_per_call,derived")
    run()
