"""Chaos tier: kill executors/endpoints and restart the WHOLE fabric
mid-workflow, at increasing fault rates, against a journaled fabric.

Each round runs a standalone task stream plus a chain workflow on a
two-endpoint fabric with a write-ahead journal. While work is in flight a
chaos loop hard-kills random executors, kills whole endpoints (never the
last live one), and — once per faulty round — simulates a full fabric crash:
``journal.close()`` (a crashed process writes nothing further), shutdown,
rebuild, ``FunctionService.resume``. A round passes only if

- every standalone task reaches a committed terminal record,
- the workflow run completes with the exact chain output (each node's
  committed effect applied exactly once), and
- the journal fold shows ZERO duplicate terminal commitments
  (``duplicate_completions == 0`` — the journal-verified exactly-once check).

The fabric runs with the data tier engaged: every payload carries an array
above the spill threshold (so journaled envelopes hold DataRefs into a
filesystem store that survives the restart), and the forwarder runs with
ETA-overrun backup speculation enabled — duplicate commitments must stay at
zero even when stragglers get backup copies mid-chaos.

Reported: p99 task latency per fault rate and its inflation over the
fault-free baseline, plus the fabric's duplicate/resume counters. The p99
inflation must stay bounded (generously: detection + failover + a full
restart are all on the measured path).
"""
from __future__ import annotations

import json
import os
import random
import tempfile
import time

import numpy as np

from repro.core import (
    FileSystemStore,
    Forwarder,
    FunctionService,
    Workflow,
    WorkflowNode,
)

from .common import emit, percentile, scaled, sleeper, smoke_mode

TASK_S = 0.02
# Generous: a round exits as soon as all work is committed, so the deadline
# only matters on a heavily loaded machine (e.g. running right after the
# jax-compiling test files), where detection/failover/restart all stretch.
ROUND_DEADLINE_S = 120.0
SPILL_THRESHOLD = 32 * 1024
PAD_FLOATS = 16 * 1024  # 64 KiB ndarray per task payload: forces a spill


def bump(doc):
    """Chain-node effect: committed exactly once per node, so a K-node chain
    over document 0 must output exactly K."""
    return doc + 1


def _build(journal_dir, with_journal=True):
    fwd = Forwarder(
        policy="least_outstanding",
        liveness_threshold_s=0.5,
        watchdog_interval_s=0.02,
        speculation=True,
        speculation_eta_factor=3.0,
        # min age is many multiples of TASK_S so backups target genuine
        # stragglers (killed executors), not tasks merely slowed by CPU
        # contention — a backup storm under load is its own chaos source
        speculation_min_age_s=0.5,
    )
    # the blob store lives beside the WAL: a restarted fabric re-attaches it
    # by path and journaled ref-bearing payloads stay resolvable
    svc = FunctionService(
        forwarder=fwd,
        journal_dir=journal_dir if with_journal else None,
        datastore=FileSystemStore(os.path.join(journal_dir, "store")),
        spill_threshold=SPILL_THRESHOLD,
    )
    for i in range(2):
        svc.make_endpoint(
            f"chaos{i}", n_executors=2, workers_per_executor=2,
            heartbeat_interval_s=0.05, heartbeat_threshold=0.5,
            elastic=True, max_executors=4,
        )
    fid_bump = svc.register_function(bump, name="chaos_bump")
    fid_sleep = svc.register_function(sleeper, name="chaos_sleep")
    return svc, fid_bump, fid_sleep


def _chain(fid, length):
    nodes = [WorkflowNode("n0", fid, max_retries=5, max_attempts=3)]
    for i in range(1, length):
        nodes.append(WorkflowNode(
            f"n{i}", fid, deps=[f"n{i-1}"], max_retries=5, max_attempts=3,
        ))
    return Workflow(nodes, name="chaos-chain")


def _round(rate, rng, tmpdir, n_tasks, chain_len):
    wal = os.path.join(tmpdir, f"wal_{int(rate * 100)}_{rng.randrange(1 << 30)}")
    svc, fid_bump, fid_sleep = _build(wal)
    wf = _chain(fid_bump, chain_len)

    t0 = time.monotonic()
    done_at = {}

    def observe(f):
        done_at.setdefault(f.task_id, time.monotonic())

    # every payload carries a 64 KiB array above the spill threshold, so the
    # whole chaos sweep (kills, site outages, full restart + resume) runs on
    # ref-bearing journaled payloads backed by the filesystem store
    pad = np.arange(PAD_FLOATS, dtype=np.float32)
    futs = svc.batch_run(
        fid_sleep,
        [{"i": i, "t": TASK_S, "pad": pad} for i in range(n_tasks)],
        max_retries=5,
    )
    task_ids = [f.task_id for f in futs]
    for f in futs:
        f.add_done_callback(observe)
    run = wf.start(svc, 0)

    restarts = 0
    restart_pending = bool(rate)  # every faulty round restarts the fabric once
    deadline = t0 + ROUND_DEADLINE_S
    while time.monotonic() < deadline:
        if (not restart_pending and len(done_at) >= len(task_ids)
                and run.done()):
            break
        time.sleep(0.05)
        if not rate:
            continue
        if rng.random() < rate:  # hard-kill a random executor
            ep = rng.choice(list(svc.endpoints.values()))
            with ep._exlock:
                n_ex = len(ep.executors)
            if n_ex:
                ep.kill_executor(rng.randrange(n_ex))
        if rng.random() < rate / 4:  # site outage (never the last live one)
            live = [
                ep for ep in svc.endpoints.values() if ep.is_alive(None)
            ]
            if len(live) > 1:
                rng.choice(live).kill()
        if restart_pending and (
            len(done_at) >= max(1, len(task_ids) // 4)
            or rng.random() < rate / 3
        ):
            # full fabric crash + restart: the journal stops cold, the whole
            # process state is discarded, and resume() re-drives only work
            # without a committed terminal record
            restart_pending = False
            restarts += 1
            svc.journal.close()
            svc.shutdown()
            svc, fid_bump, fid_sleep = _build(wal, with_journal=False)
            report = svc.resume(journal_dir=wal, workflows=[wf])
            for f in report.futures.values():
                f.add_done_callback(observe)
            run = report.runs.get(run.run_id, run)

    missing = [t for t in task_ids if t not in done_at]
    assert not missing, f"rate {rate}: {len(missing)} tasks never completed"
    assert run.done() and run.state == "SUCCEEDED", (
        f"rate {rate}: run {run.run_id} ended {run.state}"
    )
    out = run.wait(1)
    assert out == chain_len, (
        f"rate {rate}: chain output {out} != {chain_len} "
        "(a node effect committed zero or multiple times)"
    )
    # A result can resolve its future in the instant between journal.close()
    # and shutdown() during the simulated crash: the "crashed" journal drops
    # that terminal record, resume() re-drives the task, and the loop above
    # (keyed on futures) exits before the re-driven copy commits. Wait out
    # that convergence before folding — the property is that every task ends
    # committed, not that commitment races the future.
    def _fold():
        return svc.journal.state()

    st = _fold()
    while (
        any(t not in st.tasks or not st.tasks[t].terminal for t in task_ids)
        and time.monotonic() < deadline
    ):
        time.sleep(0.05)
        st = _fold()
    assert st.duplicate_completions == 0, (
        f"rate {rate}: {st.duplicate_completions} duplicate terminal records"
    )
    for tid in task_ids:
        assert st.tasks[tid].terminal, f"rate {rate}: {tid} not committed"
    dup = svc.metrics.counter("journal.duplicate_results").value
    svc.shutdown()
    lats = [done_at[t] - t0 for t in task_ids]
    return lats, restarts, dup


def run():
    rows = []
    rng = random.Random(1234)
    n_tasks = scaled(40, 10)
    chain_len = scaled(6, 4)
    rounds = scaled(3, 1)
    rates = (0.0, 0.35) if smoke_mode() else (0.0, 0.15, 0.35)

    base_p99 = None
    sweep = []
    with tempfile.TemporaryDirectory(prefix="repro-chaos-") as tmpdir:
        for rate in rates:
            lats, restarts, dups = [], 0, 0
            for _ in range(rounds):
                round_lats, round_restarts, round_dups = _round(
                    rate, rng, tmpdir, n_tasks, chain_len
                )
                lats.extend(round_lats)
                restarts += round_restarts
                dups += round_dups
            p99 = percentile(lats, 99)
            if rate == 0.0:
                base_p99 = p99
                sweep.append({"rate": rate, "p99_s": p99, "inflation": 1.0,
                              "restarts": 0, "duplicate_results": dups})
                rows.append(emit("chaos/p99_base", p99 * 1e6,
                                 f"{rounds} fault-free rounds"))
                continue
            inflation = p99 / base_p99 if base_p99 else float("nan")
            sweep.append({"rate": rate, "p99_s": p99, "inflation": inflation,
                          "restarts": restarts, "duplicate_results": dups})
            rows.append(emit(
                f"chaos/p99_rate_{int(rate * 100)}", p99 * 1e6,
                f"inflation {inflation:.1f}x; {restarts} fabric restarts; "
                f"{dups} duped results",
            ))
            # bounded p99 inflation: detection + failover + a full fabric
            # restart are all on the measured path, so the bound is generous
            # — the property is "bounded", not "small"
            assert p99 <= max(50 * base_p99, 5.0), (
                f"rate {rate}: p99 {p99:.2f}s vs base {base_p99:.2f}s"
            )

    out = os.path.join(os.path.dirname(__file__), "results", "chaos.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(
            {
                "n_tasks": n_tasks, "chain_len": chain_len,
                "rounds_per_rate": rounds, "task_s": TASK_S,
                "sweep": sweep,
            },
            f, indent=1,
        )
    return rows
