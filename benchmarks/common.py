"""Shared benchmark helpers: CSV emission in the required format."""
from __future__ import annotations

import os
import time
from typing import Any, Dict


import numpy as np


def smoke_mode() -> bool:
    """CI smoke runs (benchmarks/run.py --smoke) use tiny parameters so the
    whole suite finishes in seconds while still exercising every code path."""
    return os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def scaled(n: int, smoke_n: int) -> int:
    return smoke_n if smoke_mode() else n


def emit(name: str, us_per_call: float, derived: str = "") -> Dict[str, Any]:
    row = {"name": name, "us_per_call": round(us_per_call, 2), "derived": derived}
    print(f"{row['name']},{row['us_per_call']},{row['derived']}", flush=True)
    return row


def noop(doc):
    return doc


def sleeper(doc):
    time.sleep(doc.get("t", 0.0))
    return {"i": doc.get("i", 0)}


def percentile(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if xs else float("nan")
