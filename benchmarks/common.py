"""Shared benchmark helpers: CSV emission in the required format."""
from __future__ import annotations

import time
from typing import Any, Dict, List

import numpy as np


def emit(name: str, us_per_call: float, derived: str = "") -> Dict[str, Any]:
    row = {"name": name, "us_per_call": round(us_per_call, 2), "derived": derived}
    print(f"{row['name']},{row['us_per_call']},{row['derived']}", flush=True)
    return row


def noop(doc):
    return doc


def sleeper(doc):
    time.sleep(doc.get("t", 0.0))
    return {"i": doc.get("i", 0)}


def percentile(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if xs else float("nan")
