"""DAG workflow engine vs. the linear Flow on the same graph (paper §7).

The §7 science scenarios are multi-step pipelines; the DAG engine runs
independent branches concurrently (and ships each ready set as ONE TaskBatch
frame), so a diamond graph — source → two parallel branches → join — has a
critical path of 3 task-times where the linear Flow pays all 4 sequentially.

Rows:
    workflow/diamond_dag       per-graph latency + graphs/s via Workflow
    workflow/sequential_flow   the same 4 steps as a linear Flow
    workflow/speedup           DAG vs. Flow throughput ratio (must be >= 1)
    workflow/sibling_batching  TaskBatch frames per graph (3, not 4: the two
                               branch nodes ride one frame)

Also writes ``benchmarks/results/workflow.json`` (params + throughputs +
frame accounting), uploaded by CI's bench-smoke job.

Run standalone:  PYTHONPATH=src python -m benchmarks.bench_workflow --smoke
(or directly:    python benchmarks/bench_workflow.py --smoke)
"""
from __future__ import annotations

import json
import os
import time

if __package__ in (None, ""):  # direct-file run: python benchmarks/bench_workflow.py
    import sys

    _here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, _here)
    sys.path.insert(0, os.path.join(os.path.dirname(_here), "src"))
    from common import emit, scaled, sleeper
else:
    from .common import emit, scaled, sleeper

from repro.core import ActionStep, Flow, FunctionService, Workflow, WorkflowNode

N_GRAPHS = scaled(30, 8)
TASK_S = 0.03
WORKERS = 4


def _service():
    svc = FunctionService()
    svc.make_endpoint("bench-wf", n_executors=1, workers_per_executor=WORKERS)
    fid = svc.register_function(sleeper, name="sleeper")
    return svc, fid


def _bench_dag():
    svc, fid = _service()
    wf = Workflow([
        WorkflowNode("src", fid),
        WorkflowNode("a", fid, deps=["src"],
                     prepare=lambda doc, up: {"i": 1, "t": TASK_S}),
        WorkflowNode("b", fid, deps=["src"],
                     prepare=lambda doc, up: {"i": 2, "t": TASK_S}),
        WorkflowNode("join", fid, deps=["a", "b"],
                     prepare=lambda doc, up: {"i": 3, "t": TASK_S}),
    ], name="diamond")
    t0 = time.monotonic()
    for i in range(N_GRAPHS):
        run = wf.start(svc, {"i": i, "t": TASK_S})
        out = run.wait(60)
        assert out == {"i": 3}, out
    dt = time.monotonic() - t0
    fstats = svc.forwarder.stats()
    snap = svc.metrics.snapshot()
    svc.shutdown()
    return dt, fstats, snap


def _bench_flow():
    svc, fid = _service()
    flow = Flow([
        ActionStep(fid, name=f"s{i}", prepare=lambda doc: {"i": doc["i"], "t": TASK_S})
        for i in range(4)
    ])
    t0 = time.monotonic()
    for i in range(N_GRAPHS):
        run = flow.start(svc, {"i": i, "t": TASK_S})
        Flow.wait(run, timeout=60)
    dt = time.monotonic() - t0
    svc.shutdown()
    return dt


def run():
    rows = []
    dag_dt, fstats, snap = _bench_dag()
    counters = snap["counters"]
    flow_dt = _bench_flow()

    dag_tput = N_GRAPHS / dag_dt
    flow_tput = N_GRAPHS / flow_dt
    speedup = dag_tput / flow_tput
    frames_per_graph = fstats["batches_delivered"] / N_GRAPHS
    tasks_per_graph = fstats["tasks_delivered"] / N_GRAPHS

    # the point of the diamond: parallel branches beat the sequential chain
    assert speedup >= 1.0, (
        f"DAG throughput below sequential Flow: {dag_tput:.2f} vs {flow_tput:.2f} graphs/s"
    )
    # sibling branches ride one frame: 3 deliveries per 4-node graph
    assert frames_per_graph == 3.0 and tasks_per_graph == 4.0, (
        f"expected 3 frames / 4 tasks per graph, got {frames_per_graph}/{tasks_per_graph}"
    )
    assert counters.get("workflow.runs{state=succeeded}", 0) == N_GRAPHS

    rows.append(emit(
        "workflow/diamond_dag",
        dag_dt / N_GRAPHS * 1e6,
        f"{dag_tput:.1f} graphs/s ({N_GRAPHS} diamond graphs, task={TASK_S*1e3:.0f}ms)",
    ))
    rows.append(emit(
        "workflow/sequential_flow",
        flow_dt / N_GRAPHS * 1e6,
        f"{flow_tput:.1f} graphs/s (same 4 steps, linear)",
    ))
    rows.append(emit(
        "workflow/speedup",
        0.0,
        f"{speedup:.2f}x DAG over linear (critical path 3 vs 4 task-times)",
    ))
    rows.append(emit(
        "workflow/sibling_batching",
        0.0,
        f"{frames_per_graph:.0f} TaskBatch frames per graph for "
        f"{tasks_per_graph:.0f} nodes (siblings share one frame)",
    ))

    out = os.path.join(os.path.dirname(__file__), "results", "workflow.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(
            {
                "graphs": N_GRAPHS,
                "task_s": TASK_S,
                "workers": WORKERS,
                "dag_graphs_per_s": round(dag_tput, 2),
                "flow_graphs_per_s": round(flow_tput, 2),
                "speedup": round(speedup, 3),
                "frames_per_graph": frames_per_graph,
                "tasks_per_graph": tasks_per_graph,
                "node_latency_s": snap["histograms"].get("workflow.node_latency_s"),
            },
            f,
            indent=1,
        )
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny parameters for CI smoke runs")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        N_GRAPHS = scaled(30, 8)
    print("name,us_per_call,derived")
    run()
