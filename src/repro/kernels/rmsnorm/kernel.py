"""Pallas TPU fused residual-add + RMSNorm.

One (block_rows x D) tile per grid step: the add, the fp32 square-mean
reduction, the rsqrt and the scale all happen in VMEM; HBM sees exactly one
read of x/delta and one write of each output (the unfused XLA-CPU path
materializes the fp32 sum and the normalized intermediate separately —
visible in the dry-run's unfused byte counts).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._compat import CompilerParams


def _kernel(x_ref, d_ref, s_ref, res_ref, out_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    res = x + d
    var = jnp.mean(res * res, axis=-1, keepdims=True)
    normed = res * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    res_ref[...] = res.astype(res_ref.dtype)
    out_ref[...] = normed.astype(out_ref.dtype)


def fused_add_rmsnorm_pallas(
    x: jnp.ndarray,          # (..., D)
    delta: jnp.ndarray,
    scale: jnp.ndarray,      # (D,)
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: bool = False,
):
    orig_shape = x.shape
    D = x.shape[-1]
    x2 = x.reshape(-1, D)
    d2 = delta.reshape(-1, D)
    T = x2.shape[0]
    block_rows = min(block_rows, max(T, 8))
    pad = (-T) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        d2 = jnp.pad(d2, ((0, pad), (0, 0)))
    n_blocks = x2.shape[0] // block_rows

    res, out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2.shape, x.dtype),
            jax.ShapeDtypeStruct(x2.shape, x.dtype),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, d2, scale)
    if pad:
        res, out = res[:T], out[:T]
    return res.reshape(orig_shape), out.reshape(orig_shape)
