"""Dispatching wrapper: Pallas fused add+RMSNorm on TPU, jnp ref elsewhere."""
from __future__ import annotations

import jax

from . import ref


def fused_add_rmsnorm(x, delta, scale, eps: float = 1e-5, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return ref.fused_add_rmsnorm_reference(x, delta, scale, eps)
    from . import kernel

    return kernel.fused_add_rmsnorm_pallas(
        x, delta, scale, eps, interpret=(impl == "pallas_interpret")
    )
