"""Pure-jnp oracle for fused residual-add + RMSNorm."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def fused_add_rmsnorm_reference(
    x: jnp.ndarray,          # (..., D) residual stream
    delta: jnp.ndarray,      # (..., D) block output to add
    scale: jnp.ndarray,      # (D,)
    eps: float = 1e-5,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (new_residual = x + delta, normed(new_residual) * scale).
    The pervasive transformer pattern; fusing keeps the fp32 intermediate in
    VMEM instead of round-tripping two (T, D) tensors through HBM."""
    res = (x.astype(jnp.float32) + delta.astype(jnp.float32))
    var = jnp.mean(res * res, axis=-1, keepdims=True)
    normed = res * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return res.astype(x.dtype), normed.astype(x.dtype)
