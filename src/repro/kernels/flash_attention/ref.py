"""Pure-jnp oracle for GQA/causal attention (the Pallas kernel's reference).

Also the execution path on non-TPU backends and inside the dry-run (the
compiled HLO of this code is what cost_analysis measures; the Pallas kernel
is the TPU-target drop-in).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def mha_reference(
    q: jnp.ndarray,            # (B, Sq, H, hd)
    k: jnp.ndarray,            # (B, Skv, KV, hd)
    v: jnp.ndarray,            # (B, Skv, KV, hd)
    *,
    causal: bool = True,
    q_offset: Optional[jnp.ndarray] = None,  # scalar: absolute pos of q[0]
    kv_len: Optional[jnp.ndarray] = None,    # scalar: #valid kv positions
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Grouped-query attention with optional causal masking and a kv validity
    length (decode: q_offset = cache position, kv_len = cache fill level)."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    assert H % KV == 0, (H, KV)
    G = H // KV
    scale = scale if scale is not None else hd ** -0.5

    qg = q.reshape(B, Sq, KV, G, hd)
    # scores: (B, KV, G, Sq, Skv) in fp32
    s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale

    kv_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        q_pos = jnp.arange(Sq) + (q_offset if q_offset is not None else 0)
        mask = mask & (kv_pos[None, :] <= q_pos[:, None])
    if kv_len is not None:
        kl = jnp.asarray(kv_len)
        if kl.ndim == 0:
            mask = mask & (kv_pos[None, :] < kl)
        else:  # per-batch-row validity length (B,)
            mask = mask[None] & (kv_pos[None, None, :] < kl[:, None, None])
    if mask.ndim == 2:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    else:  # (B, Sq, Skv) -> broadcast over (KV, G)
        s = jnp.where(mask[:, None, None], s, NEG_INF)

    w = jnp.exp(s - s.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)  # dv may differ (MLA)


def decode_attention_reference(
    q: jnp.ndarray,           # (B, 1, H, hd) — single new token
    k_cache: jnp.ndarray,     # (B, S, KV, hd)
    v_cache: jnp.ndarray,     # (B, S, KV, hd)
    pos: jnp.ndarray,         # scalar or (B,) int: write/attend position
    *,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """One-token attention against a cache whose entries <= pos are valid
    (the new token's own k/v are assumed already written at `pos`).
    Vector `pos` gives per-sequence positions (continuous batching)."""
    return mha_reference(
        q, k_cache, v_cache, causal=False, kv_len=pos + 1, scale=scale
    )
