"""Pallas TPU flash attention (causal, GQA) with explicit BlockSpec tiling.

TPU-native adaptation: (block_q x hd) / (block_k x hd) tiles stream through
VMEM; the online-softmax accumulator/max/denominator live in VMEM scratch;
the MXU sees hardware-aligned (128-default) matmul tiles; q_offset / kv_len
arrive via scalar prefetch (SMEM). The S^2 score matrix never touches HBM —
this is the kernel the roofline memory model assumes on the TPU target.

Validated against ref.mha_reference in interpret mode (CPU) by
tests/test_kernels_flash.py across shape/dtype/causal/GQA sweeps.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

from .ref import NEG_INF


def _flash_kernel(
    meta_ref,     # scalar prefetch: (2,) int32 [q_offset, kv_len]
    q_ref,        # (1, block_q, hd)
    k_ref,        # (1, block_k, hd)
    v_ref,        # (1, block_k, hd)
    o_ref,        # (1, block_q, hd)
    acc_ref,      # (block_q, hd) f32 VMEM scratch
    m_ref,        # (block_q, 1) f32
    l_ref,        # (block_q, 1) f32
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    n_k_blocks: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    q_offset = meta_ref[0]
    kv_len = meta_ref[1]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q) + q_offset
    k_pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)

    def compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                            # (bq, bk)
        mask = k_pos[None, :] < kv_len
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_prev * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[:, 0] = m_new

    if causal:
        # tile is dead iff its lowest k position exceeds the tile's highest
        # absolute q position (q_offset is dynamic: evaluate inside pl.when)
        live = (ik * block_k) <= (iq * block_q + block_q - 1 + q_offset)

        @pl.when(live)
        def _():
            compute()
    else:
        compute()

    @pl.when(ik == n_k_blocks - 1)
    def _finalize():
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention_pallas(
    q: jnp.ndarray,            # (B, Sq, H, hd)
    k: jnp.ndarray,            # (B, Skv, KV, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset=None,
    kv_len=None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    assert H % KV == 0, (H, KV)
    G = H // KV
    scale = scale if scale is not None else hd ** -0.5
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Skv, 8))

    qt = _pad_to(jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, hd), 1, block_q)
    kt = _pad_to(jnp.moveaxis(k, 2, 1).reshape(B * KV, Skv, hd), 1, block_k)
    vt = _pad_to(jnp.moveaxis(v, 2, 1).reshape(B * KV, Skv, hd), 1, block_k)
    Sq_p, Skv_p = qt.shape[1], kt.shape[1]
    n_q, n_k = Sq_p // block_q, Skv_p // block_k

    q_off = jnp.asarray(0 if q_offset is None else q_offset, jnp.int32)
    klen = jnp.asarray(Skv if kv_len is None else kv_len, jnp.int32)
    meta = jnp.stack([q_off, klen]).astype(jnp.int32)

    def kv_index(bh, iq, ik, meta):  # noqa: ARG001 — grid ids first, scalar ref last
        return ((bh // H) * KV + (bh % H) // G, ik, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, n_k_blocks=n_k,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik, meta: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik, meta: (bh, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, Sq_p, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(meta, qt, kt, vt)
    out = out[:, :Sq].reshape(B, H, Sq, hd)
    return jnp.moveaxis(out, 1, 2)


def decode_attention_pallas(q, k_cache, v_cache, pos, *, scale=None, interpret=False):
    """Single-token attention: the flash kernel with Sq=1 per (batch, head)
    and kv_len = pos + 1 (scalar, or per-row via vmap)."""
    if jnp.ndim(pos) == 0:
        return flash_attention_pallas(
            q, k_cache, v_cache, causal=False, kv_len=pos + 1, scale=scale,
            interpret=interpret,
        )
    fn = lambda qb, kb, vb, pb: flash_attention_pallas(
        qb[None], kb[None], vb[None], causal=False, kv_len=pb + 1, scale=scale,
        interpret=interpret,
    )[0]
    return jax.vmap(fn)(q, k_cache, v_cache, pos)
