"""Dispatching wrapper: Pallas flash attention on TPU, jnp reference elsewhere.

``impl``: "auto" (pallas on TPU backends, ref otherwise), "pallas",
"pallas_interpret" (kernel body on CPU — used by the validation tests), "ref".
"""
from __future__ import annotations

from typing import Optional

import jax

from . import ref


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    q_offset=None,
    kv_len=None,
    scale: Optional[float] = None,
    impl: str = "auto",
    block_q: int = 128,
    block_k: int = 128,
):
    """GQA attention. q (B,Sq,H,hd); k,v (B,Skv,KV,hd) -> (B,Sq,H,hd)."""
    if impl == "auto":
        impl = _default_impl()
    if impl == "ref":
        return ref.mha_reference(
            q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len, scale=scale
        )
    from . import kernel  # deferred: pallas import is TPU-lowering-only

    return kernel.flash_attention_pallas(
        q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len, scale=scale,
        block_q=block_q, block_k=block_k, interpret=(impl == "pallas_interpret"),
    )


def decode_attention(q, k_cache, v_cache, pos, *, scale=None, impl: str = "auto"):
    """Single-token attention against a cache; entries <= pos are valid."""
    if impl == "auto":
        impl = _default_impl()
    if impl == "ref":
        return ref.decode_attention_reference(q, k_cache, v_cache, pos, scale=scale)
    from . import kernel

    return kernel.decode_attention_pallas(
        q, k_cache, v_cache, pos, scale=scale, interpret=(impl == "pallas_interpret")
    )
