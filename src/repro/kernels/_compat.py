"""jax-version compatibility for Pallas TPU kernels.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; support
both so the kernels run on the 0.4.x toolchain baked into this environment
and on current jax.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
