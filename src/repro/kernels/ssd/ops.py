"""Dispatching wrapper: Pallas SSD scan on TPU, jnp reference elsewhere."""
from __future__ import annotations


import jax

from . import ref


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def ssd(
    x,
    dt,
    A,
    B_,
    C_,
    *,
    chunk: int = 256,
    initial_state=None,
    return_final_state: bool = False,
    impl: str = "auto",
):
    """Mamba2 SSD scan. x (B,S,H,P), dt (B,S,H), A (H,), B_/C_ (B,S,G,N)."""
    if impl == "auto":
        impl = _default_impl()
    if impl == "ref":
        return ref.ssd_reference(
            x, dt, A, B_, C_, chunk=chunk, initial_state=initial_state,
            return_final_state=return_final_state,
        )
    from . import kernel  # deferred pallas import

    return kernel.ssd_pallas(
        x, dt, A, B_, C_, chunk=chunk, initial_state=initial_state,
        return_final_state=return_final_state, interpret=(impl == "pallas_interpret"),
    )


def ssd_decode(state, x_t, dt_t, A, B_t, C_t):
    """O(1) single-token SSD recurrence (no kernel needed: bandwidth-trivial)."""
    return ref.ssd_decode_reference(state, x_t, dt_t, A, B_t, C_t)
