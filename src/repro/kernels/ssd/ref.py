"""Pure-jnp oracle for the Mamba2 SSD (state-space duality) scan.

Implements the chunked block decomposition of Mamba2 (arXiv:2405.21060 §6):
within-chunk quadratic term + inter-chunk recurrence on the (H, hd, N) state.
This is the reference the Pallas kernel is validated against, the non-TPU
execution path, and the dry-run HLO.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k], lower-tri."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_reference(
    x: jnp.ndarray,     # (B, S, H, P)   inputs (already multiplied by nothing; dt applied inside)
    dt: jnp.ndarray,    # (B, S, H)      softplus-activated step sizes
    A: jnp.ndarray,     # (H,)           negative decay rates (A = -exp(A_log))
    B_: jnp.ndarray,    # (B, S, G, N)
    C_: jnp.ndarray,    # (B, S, G, N)
    *,
    chunk: int = 256,
    initial_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
    return_final_state: bool = False,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """y[t] = C[t] · h[t],  h[t] = exp(dt[t]·A)·h[t-1] + dt[t]·B[t]⊗x[t].

    Group dim G broadcasts over heads (H % G == 0).
    """
    Bb, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    f32 = jnp.float32
    x_ = x.astype(f32).reshape(Bb, nc, chunk, H, P)
    dt_ = dt.astype(f32).reshape(Bb, nc, chunk, H)
    Bc = jnp.repeat(B_.astype(f32), rep, axis=2).reshape(Bb, nc, chunk, H, N)
    Cc = jnp.repeat(C_.astype(f32), rep, axis=2).reshape(Bb, nc, chunk, H, N)

    dA = dt_ * A.astype(f32)[None, None, None, :]          # (B, nc, c, H)
    dA = jnp.moveaxis(dA, -1, 2)                            # (B, nc, H, c)
    dA_cum = jnp.cumsum(dA, axis=-1)                        # within-chunk cumsum

    # 1) within-chunk (quadratic) term: Y_diag = (C B^T ∘ L) · (dt·x)
    L = jnp.exp(segsum(dA))                                 # (B, nc, H, c, c)
    CB = jnp.einsum("bnchj,bnshj->bnhcs", Cc, Bc)           # (B, nc, H, c, c)
    dtx = x_ * dt_[..., None]                                # (B, nc, c, H, P)
    y_diag = jnp.einsum("bnhcs,bnshp->bnchp", CB * L, dtx)

    # 2) per-chunk final states: decay each position to chunk end
    decay_to_end = jnp.exp(dA_cum[..., -1:] - dA_cum)       # (B, nc, H, c)
    states = jnp.einsum("bnhc,bnchm,bnchp->bnhpm",
                        decay_to_end, Bc, dtx)               # (B, nc, H, P, N)

    # 3) inter-chunk recurrence (sequential over nc chunks)
    chunk_decay = jnp.exp(dA_cum[..., -1])                  # (B, nc, H)
    h0 = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((Bb, H, P, N), f32)
    )

    def step(h, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h

    states_t = jnp.moveaxis(states, 1, 0)                   # (nc, B, H, P, N)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)               # (nc, B, H)
    h_final, h_prior = jax.lax.scan(step, h0, (states_t, decay_t))
    h_prior = jnp.moveaxis(h_prior, 0, 1)                   # (B, nc, H, P, N): state entering chunk

    # 4) inter-chunk output: decayed prior state read out by C
    state_decay = jnp.exp(dA_cum)                           # (B, nc, H, c)
    y_off = jnp.einsum("bnchm,bnhpm,bnhc->bnchp", Cc, h_prior, state_decay)

    y = (y_diag + y_off).reshape(Bb, S, H, P).astype(x.dtype)
    return (y, h_final) if return_final_state else (y, None)


def ssd_decode_reference(
    state: jnp.ndarray,  # (B, H, P, N)
    x_t: jnp.ndarray,    # (B, H, P)
    dt_t: jnp.ndarray,   # (B, H)
    A: jnp.ndarray,      # (H,)
    B_t: jnp.ndarray,    # (B, G, N)
    C_t: jnp.ndarray,    # (B, G, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token recurrence: O(1) in sequence length."""
    Bb, H, P, N = state.shape
    G = B_t.shape[1]
    rep = H // G
    f32 = jnp.float32
    Bh = jnp.repeat(B_t.astype(f32), rep, axis=1)   # (B, H, N)
    Ch = jnp.repeat(C_t.astype(f32), rep, axis=1)
    dA = jnp.exp(dt_t.astype(f32) * A.astype(f32)[None, :])      # (B, H)
    dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt_t.astype(f32), Bh, x_t.astype(f32))
    new_state = state.astype(f32) * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x_t.dtype), new_state.astype(state.dtype)
