"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

TPU-native adaptation of the SSD block decomposition (arXiv:2405.21060 §6):
grid = (batch, heads, chunks) with the chunk axis sequential ("arbitrary"
semantics); the inter-chunk state (P x N) is carried in VMEM scratch across
grid steps — the recurrence never round-trips HBM. Within a chunk everything
is (chunk x chunk) / (chunk x P) matmuls on the MXU; cumulative sums are
computed as lower-triangular matmuls (MXU-friendly) rather than serial scans.

Validated against ref.ssd_reference in interpret mode by
tests/test_kernels_ssd.py across shape/dtype/chunk sweeps.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams


def _ssd_kernel(
    x_ref,      # (1, chunk, 1, P)
    dt_ref,     # (1, chunk, 1)
    A_ref,      # (1,)
    B_ref,      # (1, chunk, 1, N)
    C_ref,      # (1, chunk, 1, N)
    y_ref,      # (1, chunk, 1, P)
    state_ref,  # out: (1, 1, P, N) — final state, written on last chunk
    h_ref,      # VMEM scratch: (P, N) f32 carried state
    *,
    chunk: int,
    n_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (c, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (c,)
    A = A_ref[0].astype(jnp.float32)               # scalar
    Bm = B_ref[0, :, 0, :].astype(jnp.float32)     # (c, N)
    Cm = C_ref[0, :, 0, :].astype(jnp.float32)     # (c, N)

    dA = dt * A                                    # (c,)
    # cumulative sums as triangular matmuls (MXU-friendly, no serial scan)
    idx = jax.lax.iota(jnp.int32, chunk)
    tril_incl = (idx[:, None] >= idx[None, :]).astype(jnp.float32)     # i >= j
    dA_cum = jax.lax.dot_general(
        tril_incl, dA[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]                                        # (c,) inclusive cumsum

    # L[i,j] = exp(sum_{j+1..i} dA) for i>=j else 0
    diff = dA_cum[:, None] - dA_cum[None, :]
    L = jnp.where(idx[:, None] >= idx[None, :], jnp.exp(diff), 0.0)

    CB = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (c, c)
    dtx = x * dt[:, None]                          # (c, P)
    y_diag = jax.lax.dot_general(
        CB * L, dtx, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (c, P)

    # inter-chunk: read out carried state, then update it
    h = h_ref[...]                                 # (P, N)
    y_off = jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(dA_cum)[:, None]                   # (c, P)

    decay_to_end = jnp.exp(dA_cum[-1] - dA_cum)    # (c,)
    chunk_state = jax.lax.dot_general(
        dtx * decay_to_end[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                              # (P, N)
    h_new = h * jnp.exp(dA_cum[-1]) + chunk_state
    h_ref[...] = h_new

    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        state_ref[0, 0] = h_new.astype(state_ref.dtype)


def ssd_pallas(
    x: jnp.ndarray,     # (B, S, H, P)
    dt: jnp.ndarray,    # (B, S, H)
    A: jnp.ndarray,     # (H,)
    B_: jnp.ndarray,    # (B, S, G, N)
    C_: jnp.ndarray,    # (B, S, G, N)
    *,
    chunk: int = 256,
    initial_state: Optional[jnp.ndarray] = None,
    return_final_state: bool = False,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    assert initial_state is None, "kernel path supports zero initial state"
    Bb, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, ic: (b, ic, h)),
            pl.BlockSpec((1,), lambda b, h, ic: (h,)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, ic, rep=rep: (b, ic, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, ic, rep=rep: (b, ic, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, A, B_, C_)
    return (y, state) if return_final_state else (y, None)
