"""funcJAX: Serverless Supercomputing (funcX, 2019) as a multi-pod JAX framework.

Subpackages:
    core        the paper's FaaS platform (service, endpoints, optimizations)
    models      10 assigned LM architectures (dense/moe/ssm/hybrid/encdec/vlm)
    kernels     Pallas TPU kernels + pure-jnp oracles
    sharding    logical-axis partitioner (FSDP x TP x EP + pod axis)
    training    AdamW, step builders, FaaS-driven train loop
    serving     KV caches + continuous-batching engine
    data        prefetching pipelines
    checkpoint  async sharded checkpoint/restart
    configs     architecture configs + input shapes
    launch      mesh, multi-pod dry-run, train/serve drivers, pilot jobs
"""

__version__ = "1.0.0"
