"""Logical-axis partitioning (DP/FSDP x TP/EP/SP) with divisibility fallback.

Models annotate params/activations with *logical* axis names; this module
resolves them against the active mesh:

    "batch"   -> ("pod", "data")      (data parallel; pod axis folds in)
    "embed"   -> "data"               (FSDP: parameters 2D-sharded)
    "heads" / "kv_heads" / "mlp" / "vocab" / "experts" / "ssm_heads" -> "model"
    "seq"     -> "model" (sequence parallelism / seq-sharded KV) when requested

Resolution is greedy left-to-right per tensor: a mesh axis is used at most
once per spec, and a dim only shards if the mesh axis size divides it —
otherwise the dim replicates (e.g. 14 heads on a 16-way model axis, or 60
experts -> TP-MoE fallback). This single rule set generates every per-arch
sharding in the assignment without hand-written special cases.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Optional, Sequence


import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name -> candidate mesh axes, in preference order. Each candidate is
# an axis name or tuple of axis names (joint sharding).
DEFAULT_RULES: dict = {
    "batch": (("pod", "data"), "data"),
    # params FSDP-shard over the pod axis too (multi-pod ZeRO: optimizer
    # state halves at 512 chips — without this the pod axis only replicates)
    "embed": (("pod", "data"), "data"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "ssm_heads": ("model",),
    "state": (),
    "seq_shard": ("model",),   # sequence parallelism / seq-sharded KV cache
    "seq": (),                 # unsharded sequence
    "layers": (),
    "capacity": (("pod", "data"), "data"),
    None: (),
}


@dataclass
class MeshContext:
    mesh: Optional[Mesh]
    rules: dict

    def axis_size(self, axis) -> int:
        if self.mesh is None:
            return 1
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= self.mesh.shape.get(a, 0) or 0
                if a not in self.mesh.shape:
                    return 0
            return n
        return self.mesh.shape.get(axis, 0)


_ctx = threading.local()


def current() -> Optional[MeshContext]:
    return getattr(_ctx, "ctx", None)


def rules_for(cfg=None) -> dict:
    """Rule set for a model config. pure_dp widens the batch rule to consume
    both mesh axes (ZeRO-3: no tensor parallelism, per-layer param gathers)."""
    rules = dict(DEFAULT_RULES)
    if cfg is not None and getattr(cfg, "pure_dp", False):
        wide = (("pod", "data", "model"), ("data", "model"), ("pod", "data"), "data")
        rules["batch"] = wide
        rules["capacity"] = wide
    return rules


@contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate a mesh for logical-axis resolution AND jax sharding context."""
    prev = getattr(_ctx, "ctx", None)
    _ctx.ctx = MeshContext(mesh=mesh, rules=dict(rules or DEFAULT_RULES))
    try:
        if mesh is not None:
            with mesh:
                yield _ctx.ctx
        else:
            yield _ctx.ctx
    finally:
        _ctx.ctx = prev


def resolve_spec(
    logical: Sequence, shape: Optional[Sequence[int]] = None, ctx: Optional[MeshContext] = None
) -> P:
    """Logical names -> PartitionSpec with greedy axis assignment +
    divisibility fallback. `shape` enables the divisibility check; without it
    the first present candidate axis is used unconditionally."""
    ctx = ctx or current()
    if ctx is None or ctx.mesh is None:
        return P()
    used: set = set()
    out = []
    for d, name in enumerate(logical):
        assigned = None
        for cand in ctx.rules.get(name, ()):  # preference order
            axes = cand if isinstance(cand, tuple) else (cand,)
            if any(a not in ctx.mesh.shape for a in axes):
                continue
            if any(a in used for a in axes):
                continue
            size = ctx.axis_size(cand)
            if size <= 1:
                continue
            if shape is not None and shape[d] % size != 0:
                continue
            assigned = cand
            used.update(axes)
            break
        out.append(assigned)
    # trim trailing Nones for tidiness
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard_act(x: Any, *logical, ctx: Optional[MeshContext] = None) -> Any:
    """with_sharding_constraint on an activation via logical names. No-op
    when no mesh context is active (single-device tests/benches)."""
    ctx = ctx or current()
    if ctx is None or ctx.mesh is None:
        return x
    spec = resolve_spec(logical, shape=getattr(x, "shape", None), ctx=ctx)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def resolve_tree_specs(logical_tree: Any, aval_tree: Any, ctx: Optional[MeshContext] = None) -> Any:
    """Map a pytree of logical-axis tuples + matching pytree of avals ->
    pytree of PartitionSpec."""
    ctx = ctx or current()

    def one(logical, aval):
        return resolve_spec(tuple(logical), shape=aval.shape, ctx=ctx)

    return jax.tree.map(one, logical_tree, aval_tree, is_leaf=lambda x: isinstance(x, tuple))


def named_shardings(logical_tree: Any, aval_tree: Any, mesh: Mesh, rules: Optional[dict] = None) -> Any:
    ctx = MeshContext(mesh=mesh, rules=dict(rules or DEFAULT_RULES))
    specs = resolve_tree_specs(logical_tree, aval_tree, ctx=ctx)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
