"""Per-family transformer blocks: init / train-apply / decode-apply / cache.

A "layer" here is the unit the model stack scans over. Families:
  dense | vlm : (MLA or GQA) attention + SwiGLU MLP
  moe         : GQA attention + routed-expert FFN (+ shared experts)
  ssm         : Mamba2 block
  hybrid      : Mamba2 layers; the *shared* attention block lives in model.py
  encdec      : encoder layer (bidir attn + GELU MLP) and
                decoder layer (causal self-attn + cross-attn + GELU MLP)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import partition
from . import attention, layers, mamba2, mla, moe


def _residual_enter(h, cfg: ModelConfig):
    if cfg.sequence_parallel:
        return partition.shard_act(h, "batch", "seq_shard", None)
    return partition.shard_act(h, "batch", "seq", None)


# ---------------------------------------------------------------- dense / moe
def init_decoder_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    if cfg.mla is not None:
        attn_p, attn_s = mla.init_mla(k1, cfg)
    else:
        attn_p, attn_s = attention.init_attention(k1, cfg)
    n1, n1s = layers.init_rmsnorm(cfg.d_model)
    n2, n2s = layers.init_rmsnorm(cfg.d_model)
    if cfg.family == "moe":
        ffn_p, ffn_s = moe.init_moe(k2, cfg)
    else:
        ffn_p, ffn_s = layers.init_swiglu(k2, cfg.d_model, cfg.d_ff, layers.dtype_of(cfg))
    params = {"attn": attn_p, "ffn": ffn_p, "ln1": n1, "ln2": n2}
    specs = {"attn": attn_s, "ffn": ffn_s, "ln1": n1s, "ln2": n2s}
    return params, specs


def decoder_layer(
    p, h: jnp.ndarray, cfg: ModelConfig, positions: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[tuple]]:
    """Train/prefill. Returns (h, aux_loss, kv_for_cache)."""
    h = _residual_enter(h, cfg)
    hn = layers.rmsnorm(h, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, kv = mla.mla_attention(p["attn"], hn, cfg, positions=positions, return_cache=True)
    else:
        a, kv = attention.self_attention(
            p["attn"], hn, cfg, positions=positions, causal=True, return_kv=True
        )
    h = h + a
    hn = layers.rmsnorm(h, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        f, aux = moe.moe_ffn(hn, p["ffn"], cfg)
    else:
        f, aux = layers.swiglu(hn, p["ffn"]), jnp.float32(0.0)
    return h + f, aux, kv


def decoder_layer_decode(
    p, h: jnp.ndarray, cache: dict, pos: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, dict]:
    hn = layers.rmsnorm(h, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, (ckv, krope) = mla.mla_attention_decode(
            p["attn"], hn, cache["ckv"], cache["krope"], pos, cfg
        )
        new_cache = {"ckv": ckv, "krope": krope}
    else:
        a, (k, v) = attention.self_attention_decode(
            p["attn"], hn, cache["k"], cache["v"], pos, cfg
        )
        new_cache = {"k": k, "v": v}
    h = h + a
    hn = layers.rmsnorm(h, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        f, _ = moe.moe_ffn(hn, p["ffn"], cfg)
    else:
        f = layers.swiglu(hn, p["ffn"])
    return h + f, new_cache


def init_decoder_cache(cfg: ModelConfig, batch: int, cache_len: int):
    """Zero per-layer cache + logical specs. KV heads shard over `model` when
    divisible; otherwise the sequence dim takes the model axis (seq-sharded
    cache for the flash-decoding combine)."""
    dt = layers.dtype_of(cfg)
    if cfg.mla is not None:
        m = cfg.mla
        cache = {
            "ckv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dt),
            "krope": jnp.zeros((batch, cache_len, m.qk_rope_dim), dt),
        }
        specs = {
            "ckv": ("batch", "seq_shard", None),
            "krope": ("batch", "seq_shard", None),
        }
        return cache, specs
    kv_div = _kv_heads_shardable(cfg)
    seq_name = "seq" if kv_div else "seq_shard"
    cache = {
        "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.hd), dt),
        "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.hd), dt),
    }
    specs = {
        "k": ("batch", seq_name, "kv_heads", None),
        "v": ("batch", seq_name, "kv_heads", None),
    }
    return cache, specs


def _kv_heads_shardable(cfg: ModelConfig) -> bool:
    ctx = partition.current()
    if ctx is None or ctx.mesh is None:
        return True
    size = ctx.mesh.shape.get("model", 1)
    return size <= 1 or cfg.n_kv_heads % size == 0


# ------------------------------------------------------------------------ ssm
def init_ssm_layer(key, cfg: ModelConfig):
    m_p, m_s = mamba2.init_mamba2(key, cfg)
    n, ns = layers.init_rmsnorm(cfg.d_model)
    return {"mamba": m_p, "ln": n}, {"mamba": m_s, "ln": ns}


def ssm_layer(p, h, cfg: ModelConfig, *, return_state: bool = False):
    h = _residual_enter(h, cfg)
    hn = layers.rmsnorm(h, p["ln"], cfg.norm_eps)
    y, state = mamba2.mamba2_block(p["mamba"], hn, cfg, return_state=return_state)
    return h + y, state


def ssm_layer_decode(p, h, state: dict, cfg: ModelConfig):
    hn = layers.rmsnorm(h, p["ln"], cfg.norm_eps)
    y, new_state = mamba2.mamba2_decode(p["mamba"], hn, state, cfg)
    return h + y, new_state


# --------------------------------------------------------------------- encdec
def init_encoder_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    attn_p, attn_s = attention.init_attention(k1, cfg)
    mlp_p, mlp_s = layers.init_gelu_mlp(k2, cfg.d_model, cfg.d_ff, layers.dtype_of(cfg))
    n1, n1s = layers.init_layernorm(cfg.d_model)
    n2, n2s = layers.init_layernorm(cfg.d_model)
    return (
        {"attn": attn_p, "mlp": mlp_p, "ln1": n1, "ln2": n2},
        {"attn": attn_s, "mlp": mlp_s, "ln1": n1s, "ln2": n2s},
    )


def encoder_layer(p, h, cfg: ModelConfig):
    h = _residual_enter(h, cfg)
    hn = layers.layernorm(h, p["ln1"], cfg.norm_eps)
    a, _ = attention.self_attention(p["attn"], hn, cfg, positions=None, causal=False)
    h = h + a
    hn = layers.layernorm(h, p["ln2"], cfg.norm_eps)
    return h + layers.gelu_mlp(hn, p["mlp"])


def init_cross_decoder_layer(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    self_p, self_s = attention.init_attention(k1, cfg)
    cross_p, cross_s = attention.init_attention(k2, cfg, cross=True)
    mlp_p, mlp_s = layers.init_gelu_mlp(k3, cfg.d_model, cfg.d_ff, layers.dtype_of(cfg))
    n1, n1s = layers.init_layernorm(cfg.d_model)
    n2, n2s = layers.init_layernorm(cfg.d_model)
    n3, n3s = layers.init_layernorm(cfg.d_model)
    return (
        {"self": self_p, "cross": cross_p, "mlp": mlp_p, "ln1": n1, "ln2": n2, "ln3": n3},
        {"self": self_s, "cross": cross_s, "mlp": mlp_s, "ln1": n1s, "ln2": n2s, "ln3": n3s},
    )


def cross_decoder_layer(p, h, enc_out, cfg: ModelConfig):
    """Train/prefill decoder layer. Returns (h, (self_k, self_v, cross_k, cross_v))."""
    h = _residual_enter(h, cfg)
    hn = layers.layernorm(h, p["ln1"], cfg.norm_eps)
    a, self_kv = attention.self_attention(p["self"], hn, cfg, positions=None, causal=True,
                                          return_kv=True)
    h = h + a
    hn = layers.layernorm(h, p["ln2"], cfg.norm_eps)
    c, cross_kv = attention.cross_attention(p["cross"], hn, kv_source=enc_out, cfg=cfg)
    h = h + c
    hn = layers.layernorm(h, p["ln3"], cfg.norm_eps)
    return h + layers.gelu_mlp(hn, p["mlp"]), (self_kv, cross_kv)


def cross_decoder_layer_decode(p, h, cache: dict, pos, cfg: ModelConfig):
    hn = layers.layernorm(h, p["ln1"], cfg.norm_eps)
    a, (k, v) = attention.self_attention_decode(p["self"], hn, cache["k"], cache["v"], pos, cfg)
    h = h + a
    hn = layers.layernorm(h, p["ln2"], cfg.norm_eps)
    c, _ = attention.cross_attention(
        p["cross"], hn, kv_cache=(cache["cross_k"], cache["cross_v"]), cfg=cfg
    )
    h = h + c
    hn = layers.layernorm(h, p["ln3"], cfg.norm_eps)
    h = h + layers.gelu_mlp(hn, p["mlp"])
    new_cache = dict(cache)
    new_cache.update(k=k, v=v)
    return h, new_cache
