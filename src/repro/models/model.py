"""Model assembly: embedding + scanned layer stack + head, per family.

One :class:`Model` serves all 10 assigned architectures. Stacked-per-layer
parameters + ``lax.scan`` keep the HLO O(1) in depth (a 95-layer dry-run
compiles in the same time as a 2-layer one); ``jax.checkpoint`` around the
scan body implements the remat policy.

API:
    init(key) / init_with_specs(key) / specs() / abstract_params()
    loss(params, batch)                         -> (scalar, metrics)
    forward(params, batch)                      -> (logits, aux)
    prefill(params, batch)                      -> (last_logits, cache)
    decode_step(params, token, cache, pos)      -> (logits, new_cache)
    init_cache(batch, cache_len)                -> (cache, logical_specs)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple


import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import partition
from . import blocks, layers, mamba2

AUX_COEF = 0.01


def _remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    policies = {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.checkpoint_dots,
        "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    }
    return jax.checkpoint(fn, policy=policies[cfg.remat_policy])


def _stack_init(init_fn, key, n: int):
    """vmap an init over n layer keys -> stacked params; specs get a leading
    'layers' logical axis."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    captured = {}

    def probe(k):
        p, s = init_fn(k)
        captured["s"] = s
        return p

    jax.eval_shape(probe, keys[0])  # abstract: captures static specs only
    specs = jax.tree.map(
        lambda s: ("layers", *s), captured["s"], is_leaf=lambda x: isinstance(x, tuple)
    )
    return params, specs


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ================================================================ init
    def init_with_specs(self, key) -> Tuple[Dict, Dict]:
        cfg = self.cfg
        dt = layers.dtype_of(cfg)
        keys = jax.random.split(key, 8)
        params: Dict[str, Any] = {}
        specs: Dict[str, Any] = {}

        params["embed"], specs["embed"] = layers.init_embedding(keys[0], cfg.vocab, cfg.d_model, dt)
        if not cfg.tie_embeddings:
            params["unembed"], specs["unembed"] = layers.init_unembed(
                keys[1], cfg.vocab, cfg.d_model, dt
            )
        params["final_norm"], specs["final_norm"] = (
            layers.init_layernorm(cfg.d_model)
            if cfg.family == "encdec"
            else layers.init_rmsnorm(cfg.d_model)
        )

        if cfg.family in ("dense", "moe", "vlm"):
            params["layers"], specs["layers"] = _stack_init(
                lambda k: blocks.init_decoder_layer(k, cfg), keys[2], cfg.n_layers
            )
            if cfg.family == "vlm":
                params["patch_proj"] = layers.dense_init(
                    keys[3], (cfg.d_model, cfg.d_model), cfg.d_model, dt
                )
                specs["patch_proj"] = ("embed", "mlp")
        elif cfg.family == "ssm":
            params["layers"], specs["layers"] = _stack_init(
                lambda k: blocks.init_ssm_layer(k, cfg), keys[2], cfg.n_layers
            )
        elif cfg.family == "hybrid":
            G, PG = self._hybrid_groups()
            flat, flat_specs = _stack_init(
                lambda k: blocks.init_ssm_layer(k, cfg), keys[2], cfg.n_layers
            )
            params["layers"] = jax.tree.map(
                lambda x: x.reshape(G, PG, *x.shape[1:]), flat
            )
            # params are (G, PG, ...): prepend a second "layers" name
            specs["layers"] = jax.tree.map(
                lambda s: ("layers", *s), flat_specs,
                is_leaf=lambda x: isinstance(x, tuple),
            )
            params["shared"], specs["shared"] = blocks.init_decoder_layer(keys[3], cfg)
        elif cfg.family == "encdec":
            params["enc_layers"], specs["enc_layers"] = _stack_init(
                lambda k: blocks.init_encoder_layer(k, cfg), keys[2], cfg.n_enc_layers
            )
            params["layers"], specs["layers"] = _stack_init(
                lambda k: blocks.init_cross_decoder_layer(k, cfg), keys[3], cfg.n_layers
            )
            params["enc_norm"], specs["enc_norm"] = layers.init_layernorm(cfg.d_model)
        else:
            raise ValueError(cfg.family)
        return params, specs

    def init(self, key) -> Dict:
        return self.init_with_specs(key)[0]

    def specs(self) -> Dict:
        captured: Dict[str, Any] = {}

        def f(key):
            p, s = self.init_with_specs(key)
            captured["specs"] = s
            return p

        jax.eval_shape(f, jax.random.PRNGKey(0))
        return captured["specs"]

    def abstract_params(self):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def _hybrid_groups(self) -> Tuple[int, int]:
        cfg = self.cfg
        PG = cfg.shared_attn_every
        assert cfg.n_layers % PG == 0, (cfg.n_layers, PG)
        return cfg.n_layers // PG, PG

    # ============================================================ embedding
    def _embed_inputs(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        tokens = batch["tokens"]
        h = layers.embed(tokens, params["embed"])
        if cfg.family == "vlm":
            patches = jnp.einsum("bpd,de->bpe", batch["patches"].astype(h.dtype),
                                 params["patch_proj"])
            h = jnp.concatenate([patches, h], axis=1)
        if cfg.family == "encdec":
            pos = layers.sinusoidal_positions(h.shape[1], cfg.d_model).astype(h.dtype)
            h = h + pos[None]
        return partition.shard_act(h, "batch", "seq", None)

    # ============================================================== forward
    def forward(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full-sequence forward. Returns (hidden_states, aux_loss)."""
        cfg = self.cfg
        h = self._embed_inputs(params, batch)
        S = h.shape[1]
        positions = jnp.arange(S)

        if cfg.family in ("dense", "moe", "vlm"):
            def body(carry, lp):
                hh, aux = carry
                hh, a, _ = blocks.decoder_layer(lp, hh, cfg, positions)
                return (hh, aux + a), None

            (h, aux), _ = self._scan(body, (h, jnp.float32(0.0)), params["layers"])
        elif cfg.family == "ssm":
            def body(carry, lp):
                hh, _ = blocks.ssm_layer(lp, carry[0], cfg)
                return (hh, carry[1]), None

            (h, _), _ = self._scan(body, (h, jnp.float32(0.0)), params["layers"])
            aux = jnp.float32(0.0)
        elif cfg.family == "hybrid":
            shared = params["shared"]

            def group(carry, glp):
                hh, aux = carry
                hh, a, _ = blocks.decoder_layer(shared, hh, cfg, positions)

                def inner(c, lp):
                    h2, _ = blocks.ssm_layer(lp, c, cfg)
                    return h2, None

                hh, _ = self._scan(inner, hh, glp)
                return (hh, aux + a), None

            (h, aux), _ = self._scan(group, (h, jnp.float32(0.0)), params["layers"])
        elif cfg.family == "encdec":
            enc = self._encode(params, batch)

            def body(carry, lp):
                hh, _ = blocks.cross_decoder_layer(lp, carry[0], enc, cfg)
                return (hh, carry[1]), None

            (h, _), _ = self._scan(body, (h, jnp.float32(0.0)), params["layers"])
            aux = jnp.float32(0.0)
        else:
            raise ValueError(cfg.family)

        if cfg.family == "encdec":
            h = layers.layernorm(h, params["final_norm"], cfg.norm_eps)
        else:
            h = layers.rmsnorm(h, params["final_norm"], cfg.norm_eps)
        return h, aux

    def _scan(self, body, carry, stacked):
        if self.cfg.scan_layers:
            return jax.lax.scan(_remat(body, self.cfg), carry, stacked)
        n = jax.tree.leaves(stacked)[0].shape[0]
        for i in range(n):
            lp = jax.tree.map(lambda x: x[i], stacked)
            carry, _ = _remat(body, self.cfg)(carry, lp)
        return carry, None

    def _scan_ys(self, body, carry, xs):
        """scan that also stacks per-layer outputs; honours scan_layers=False
        (unrolled — used by the dry-run so XLA cost analysis sees every layer
        instead of a single while-loop body)."""
        if self.cfg.scan_layers:
            return jax.lax.scan(_remat(body, self.cfg), carry, xs)
        n = jax.tree.leaves(xs)[0].shape[0]
        ys = []
        for i in range(n):
            xi = jax.tree.map(lambda x: x[i], xs)
            carry, y = _remat(body, self.cfg)(carry, xi)
            ys.append(y)
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls, axis=0), *ys)
        return carry, stacked

    def _encode(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        frames = batch["frames"].astype(layers.dtype_of(cfg))
        pos = layers.sinusoidal_positions(frames.shape[1], cfg.d_model).astype(frames.dtype)
        h = frames + pos[None]

        def body(carry, lp):
            return blocks.encoder_layer(lp, carry, cfg), None

        h, _ = self._scan(body, h, params["enc_layers"])
        return layers.layernorm(h, params["enc_norm"], cfg.norm_eps)

    def _logits(self, params, h: jnp.ndarray) -> jnp.ndarray:
        unembed = params.get("unembed")
        logits = layers.logits_from(h, unembed, params["embed"])
        return partition.shard_act(logits, "batch", "seq", "vocab")

    # ================================================================= loss
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        h, aux = self.forward(params, batch)
        tokens = batch["tokens"]
        if cfg.family == "vlm":
            P = cfg.n_patches
            St = tokens.shape[1]
            h_lm = jax.lax.dynamic_slice_in_dim(h, P - 1, St, axis=1)
            targets = tokens
        else:
            h_lm = h[:, :-1]
            targets = tokens[:, 1:]
        logits = self._logits(params, h_lm)
        mask = batch.get("loss_mask")
        if mask is not None and cfg.family != "vlm":
            mask = mask[:, 1:]
        ce = layers.cross_entropy_loss(logits, targets, mask)
        total = ce + AUX_COEF * aux
        return total, {"ce": ce, "aux": aux, "loss": total}

    # ============================================================== prefill
    def prefill(self, params, batch) -> Tuple[jnp.ndarray, Any]:
        """Run the full prompt, return (last-position logits (B, V), cache)."""
        cfg = self.cfg
        h = self._embed_inputs(params, batch)
        S = h.shape[1]
        positions = jnp.arange(S)

        if cfg.family in ("dense", "moe", "vlm"):
            def body(hh, lp):
                hh, _, kv = blocks.decoder_layer(lp, hh, cfg, positions)
                return hh, self._pack_kv(kv)

            h, cache = self._scan_prefill(body, h, params["layers"])
        elif cfg.family == "ssm":
            def body(hh, lp):
                hh, state = blocks.ssm_layer(lp, hh, cfg, return_state=True)
                return hh, state

            h, cache = self._scan_prefill(body, h, params["layers"])
        elif cfg.family == "hybrid":
            shared = params["shared"]

            def group(hh, glp):
                hh, _, kv = blocks.decoder_layer(shared, hh, cfg, positions)

                def inner(c, lp):
                    c, state = blocks.ssm_layer(lp, c, cfg, return_state=True)
                    return c, state

                hh, mstates = self._scan_ys(inner, hh, glp)
                return hh, {"attn": self._pack_kv(kv), "mamba": mstates}

            h, cache = self._scan_prefill(group, h, params["layers"])
        elif cfg.family == "encdec":
            enc = self._encode(params, batch)

            def body(hh, lp):
                hh, (self_kv, cross_kv) = blocks.cross_decoder_layer(lp, hh, enc, cfg)
                sk, sv = self_kv
                ck, cv = cross_kv
                return hh, {"k": sk, "v": sv, "cross_k": ck, "cross_v": cv}

            h, cache = self._scan_prefill(body, h, params["layers"])
        else:
            raise ValueError(cfg.family)

        norm = layers.layernorm if cfg.family == "encdec" else layers.rmsnorm
        h = norm(h, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, h[:, -1:])[:, 0]
        return logits, cache

    def _pack_kv(self, kv):
        if self.cfg.mla is not None:
            return {"ckv": kv[0], "krope": kv[1]}
        return {"k": kv[0], "v": kv[1]}

    def _scan_prefill(self, body, h, stacked):
        return self._scan_ys(body, h, stacked)

    # =============================================================== decode
    def decode_step(self, params, token: jnp.ndarray, cache: Any, pos: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, Any]:
        """token: (B, 1) int32; pos: scalar int32 (write position). Returns
        (logits (B, V), new_cache)."""
        cfg = self.cfg
        h = layers.embed(token, params["embed"])
        if cfg.family == "encdec":
            pe = layers.sinusoidal_positions(cache_len_of(cache), cfg.d_model)
            if pos.ndim == 1:
                h = h + jnp.take(pe, pos, axis=0)[:, None].astype(h.dtype)
            else:
                h = h + jax.lax.dynamic_slice_in_dim(pe, pos, 1, axis=0)[None].astype(h.dtype)
        h = partition.shard_act(h, "batch", "seq", None)

        if cfg.family in ("dense", "moe", "vlm"):
            def body(hh, xs):
                lp, lc = xs
                hh, nc = blocks.decoder_layer_decode(lp, hh, lc, pos, cfg)
                return hh, nc

            h, new_cache = self._scan_ys(body, h, (params["layers"], cache))
        elif cfg.family == "ssm":
            def body(hh, xs):
                lp, st = xs
                hh, ns = blocks.ssm_layer_decode(lp, hh, st, cfg)
                return hh, ns

            h, new_cache = self._scan_ys(body, h, (params["layers"], cache))
        elif cfg.family == "hybrid":
            shared = params["shared"]

            def group(hh, xs):
                glp, gc = xs
                hh, attn_nc = blocks.decoder_layer_decode(shared, hh, gc["attn"], pos, cfg)

                def inner(c, ys):
                    lp, st = ys
                    c, ns = blocks.ssm_layer_decode(lp, c, st, cfg)
                    return c, ns

                hh, mamba_nc = self._scan_ys(inner, hh, (glp, gc["mamba"]))
                return hh, {"attn": attn_nc, "mamba": mamba_nc}

            h, new_cache = self._scan_ys(group, h, (params["layers"], cache))
        elif cfg.family == "encdec":
            def body(hh, xs):
                lp, lc = xs
                hh, nc = blocks.cross_decoder_layer_decode(lp, hh, lc, pos, cfg)
                return hh, nc

            h, new_cache = self._scan_ys(body, h, (params["layers"], cache))
        else:
            raise ValueError(cfg.family)

        norm = layers.layernorm if cfg.family == "encdec" else layers.rmsnorm
        h = norm(h, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, h)[:, 0]
        return logits, new_cache

    # ================================================================ cache
    def init_cache(self, batch: int, cache_len: int) -> Tuple[Any, Any]:
        """Zero decode cache + logical axis specs (stacked over layers)."""
        cfg = self.cfg

        def stack(cache, specs, n):
            c = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), cache)
            s = jax.tree.map(lambda t: ("layers", *t), specs,
                             is_leaf=lambda x: isinstance(x, tuple))
            return c, s

        if cfg.family in ("dense", "moe", "vlm"):
            # cache_len counts TOTAL sequence slots (patches included for vlm)
            c, s = blocks.init_decoder_cache(cfg, batch, cache_len)
            return stack(c, s, cfg.n_layers)
        if cfg.family == "ssm":
            c, s = mamba2.init_decode_state(cfg, batch)
            c = {"conv": c["conv"], "ssm": c["ssm"]}
            return stack(c, s, cfg.n_layers)
        if cfg.family == "hybrid":
            G, PG = self._hybrid_groups()
            ac, asp = blocks.init_decoder_cache(cfg, batch, cache_len)
            mc, msp = mamba2.init_decode_state(cfg, batch)
            mc_stacked = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (PG, *x.shape)), mc)
            msp = jax.tree.map(lambda t: ("layers", *t), msp,
                               is_leaf=lambda x: isinstance(x, tuple))
            cache = {"attn": ac, "mamba": mc_stacked}
            specs = {"attn": asp, "mamba": msp}
            return stack(cache, specs, G)
        if cfg.family == "encdec":
            c, s = blocks.init_decoder_cache(cfg, batch, cache_len)
            dt = layers.dtype_of(cfg)
            c = dict(c)
            c["cross_k"] = jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, cfg.hd), dt)
            c["cross_v"] = jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, cfg.hd), dt)
            s = dict(s)
            s["cross_k"] = ("batch", None, "kv_heads", None)
            s["cross_v"] = ("batch", None, "kv_heads", None)
            return stack(c, s, cfg.n_layers)
        raise ValueError(cfg.family)


def cache_len_of(cache) -> int:
    """Sequence capacity of a dense-style cache (for whisper positions)."""
    leaf = cache["k"] if isinstance(cache, dict) and "k" in cache else jax.tree.leaves(cache)[0]
    return leaf.shape[2]


@functools.lru_cache(maxsize=64)
def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
