"""Mixture-of-Experts FFN with sort-based capacity dispatch.

The GShard one-hot einsum dispatch materializes a (tokens, experts, capacity)
tensor — infeasible at 1M tokens x 128 experts. Instead we build an (E, C)
token-index table by sorting assignments by expert (MegaBlocks-style grouping
without the custom kernel), gather tokens, run batched expert einsums, and
scatter-add weighted outputs back.

Sharding: experts shard over `model` (EP) when divisible — XLA inserts the
data->expert all-to-all at the gather. Otherwise (e.g. 60 experts on a 16-way
axis) experts replicate and each expert's d_ff shards over `model` (TP-MoE).
Capacity shards over the data axes either way.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, MoEConfig
from ..sharding import partition
from . import layers


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    D = cfg.d_model
    dt = layers.dtype_of(cfg)
    ks = jax.random.split(key, 6)
    params = {
        "router": (jax.random.normal(ks[0], (D, m.n_experts), jnp.float32) * D ** -0.5),
        "wi": layers.dense_init(ks[1], (m.n_experts, D, m.d_ff_expert), D, dt),
        "wg": layers.dense_init(ks[2], (m.n_experts, D, m.d_ff_expert), D, dt),
        "wo": layers.dense_init(ks[3], (m.n_experts, m.d_ff_expert, D), m.d_ff_expert, dt),
    }
    specs = {
        "router": ("embed", None),
        "wi": ("experts", "embed", "mlp"),
        "wg": ("experts", "embed", "mlp"),
        "wo": ("experts", "mlp", "embed"),
    }
    if m.n_shared_experts:
        sh, sh_specs = layers.init_swiglu(ks[4], D, m.d_ff_shared, dt)
        params["shared"] = sh
        specs["shared"] = sh_specs
        params["shared_gate"] = layers.dense_init(ks[5], (D, 1), D, dt)
        specs["shared_gate"] = ("embed", None)
    return params, specs


def _capacity(n_tokens: int, m: MoEConfig) -> int:
    c = int(m.capacity_factor * n_tokens * m.top_k / m.n_experts)
    c = max(c, 4)
    return int(-(-c // 4) * 4)  # round up to a multiple of 4


def route(x2d: jnp.ndarray, router_w: jnp.ndarray, m: MoEConfig):
    """Returns (top-k weights (T,k) fp32, top-k expert ids (T,k) int32,
    router probs for aux loss (T,E))."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, m.top_k)
    if m.norm_topk_prob:
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    return topw, topi, probs


def build_dispatch(topi: jnp.ndarray, topw: jnp.ndarray, n_tokens: int, m: MoEConfig):
    """Sort assignments by expert; keep the first C per expert (capacity
    drop). Returns (gather_idx (E*C,) int32 in [0, T] where T = dropped,
    combine_w (E*C,) fp32, C, assign_slot (T, k) int32 in [0, E*C] — the slot
    each (token, choice) landed in, E*C when dropped)."""
    E, k = m.n_experts, m.top_k
    C = _capacity(n_tokens, m)
    flat_e = topi.reshape(-1)                               # (T*k,)
    order = jnp.argsort(flat_e)                             # stable, groups by expert
    sorted_e = flat_e[order]
    # rank of each assignment within its expert group
    group_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    ranks = jnp.arange(sorted_e.shape[0], dtype=jnp.int32) - group_start.astype(jnp.int32)
    keep = ranks < C
    slot = jnp.where(keep, sorted_e * C + ranks, E * C)     # overflow -> dropped slot
    token_of = (order // k).astype(jnp.int32)
    w_of = topw.reshape(-1)[order]
    gather_idx = jnp.full((E * C + 1,), n_tokens, jnp.int32).at[slot].set(token_of)[: E * C]
    combine_w = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(w_of)[: E * C]
    # invert the permutation: slot of each original (token, choice) assignment
    assign_slot = (
        jnp.zeros((n_tokens * k,), jnp.int32).at[order].set(slot.astype(jnp.int32))
    ).reshape(n_tokens, k)
    return gather_idx, combine_w, C, assign_slot


def _local_expert_ffn(x2d, p, m: MoEConfig, e_base: int, n_local: int):
    """Dispatch+compute+combine for `n_local` experts starting at `e_base`,
    entirely on-device (no collectives). x2d: (T_loc, D) local tokens."""
    T, D = x2d.shape
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, m.top_k)
    if m.norm_topk_prob:
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    C = _capacity(T, m)
    local = topi - e_base                                   # (T, k); valid in [0, n_local)
    valid = (local >= 0) & (local < n_local)
    flat_e = jnp.where(valid, local, n_local).reshape(-1)   # invalid -> overflow group
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    group_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    ranks = jnp.arange(sorted_e.shape[0], dtype=jnp.int32) - group_start.astype(jnp.int32)
    keep = (ranks < C) & (sorted_e < n_local)
    slot = jnp.where(keep, sorted_e * C + ranks, n_local * C)
    token_of = (order // m.top_k).astype(jnp.int32)
    w_of = topw.reshape(-1)[order]
    gather_idx = jnp.full((n_local * C + 1,), T, jnp.int32).at[slot].set(token_of)[: n_local * C]
    combine_w = jnp.zeros((n_local * C + 1,), jnp.float32).at[slot].set(w_of)[: n_local * C]

    x_pad = jnp.concatenate([x2d, jnp.zeros((1, D), x2d.dtype)], axis=0)
    xe = x_pad[gather_idx].reshape(n_local, C, D)
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    y_flat = ye.reshape(n_local * C, D) * combine_w[:, None].astype(ye.dtype)
    y = jnp.zeros((T + 1, D), ye.dtype).at[gather_idx].add(y_flat)[:T]

    me = probs.mean(axis=0)
    ce = jax.nn.one_hot(topi[:, 0], m.n_experts, dtype=jnp.float32).mean(axis=0)
    aux = m.n_experts * jnp.sum(me * ce)
    return y, aux


def _moe_ffn_shard_map(x: jnp.ndarray, p, cfg: ModelConfig):
    """EP via shard_map: activations are replicated over `model` while
    experts shard over it, so NO dispatch collective is needed at all —
    each model-rank routes its (data-)local tokens to its local experts and
    the partial outputs reduce with one psum of (T_loc, D). This is the
    §Perf fix for the dense all-reduces XLA's SPMD partitioner emits for the
    global scatter/gather formulations (see EXPERIMENTS.md)."""
    from jax.sharding import PartitionSpec as P

    try:  # jax>=0.6 moved shard_map to the top level
        from jax import shard_map as _shard_map
        shard_map = _shard_map
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map

    import inspect

    # the replication-check kwarg was renamed check_rep -> check_vma
    check_kw = (
        "check_vma"
        if "check_vma" in inspect.signature(shard_map).parameters
        else "check_rep"
    )

    ctx = partition.current()
    mesh = ctx.mesh
    m = cfg.moe
    n_model = mesh.shape.get("model", 1)
    n_local = m.n_experts // n_model
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    B, S, D = x.shape

    def body(xb, router, wi, wg, wo):
        rank = jax.lax.axis_index("model")
        x2d = xb.reshape(-1, D)
        pp = {"router": router, "wi": wi, "wg": wg, "wo": wo}
        y, aux = _local_expert_ffn(x2d, pp, m, rank * n_local, n_local)
        y = jax.lax.psum(y, "model")
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return y.reshape(xb.shape), aux

    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(batch_axes if batch_axes else None),      # x: batch sharded
            P(),                                         # router replicated
            P("model"), P("model"), P("model"),          # experts over model
        ),
        out_specs=(P(batch_axes if batch_axes else None), P()),
        **{check_kw: False},
    )(x, p["router"], p["wi"], p["wg"], p["wo"])
    return y, aux


def moe_ffn(x: jnp.ndarray, p, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B,S,D), aux load-balance loss scalar)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    x2d = x.reshape(T, D)

    ctx = partition.current()
    if (
        cfg.moe_impl == "local"
        and ctx is not None
        and ctx.mesh is not None
        and ctx.mesh.shape.get("model", 1) > 1
        and m.n_experts % ctx.mesh.shape.get("model", 1) == 0
    ):
        y, aux = _moe_ffn_shard_map(x, p, cfg)
        if m.n_shared_experts:
            gate = jax.nn.sigmoid(
                jnp.einsum("bsd,dg->bsg", x, p["shared_gate"]).astype(jnp.float32)
            ).astype(x.dtype)
            y = y + gate * layers.swiglu(x, p["shared"])
        return y, aux

    topw, topi, probs = route(x2d, p["router"], m)
    gather_idx, combine_w, C, assign_slot = build_dispatch(topi, topw, T, m)

    # dispatch: (E, C, D); padded row T reads zeros
    x_pad = jnp.concatenate([x2d, jnp.zeros((1, D), x2d.dtype)], axis=0)
    xe = x_pad[gather_idx].reshape(m.n_experts, C, D)
    xe = partition.shard_act(xe, "experts", "capacity", "embed")

    # expert FFN (SwiGLU), batched over experts
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    h = partition.shard_act(h, "experts", "capacity", "mlp")
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])

    if getattr(cfg, "moe_combine", "scatter") == "gather":
        # combine as a token-side GATHER: each token pulls its k expert
        # outputs by slot id. XLA partitions gathers with all-to-all-sized
        # traffic; the scatter form below degenerates into dense all-reduces
        # of the full (T, D) activation (the §Perf hillclimb finding).
        ye_pad = jnp.concatenate(
            [ye.reshape(m.n_experts * C, D), jnp.zeros((1, D), ye.dtype)], axis=0
        )
        picked = ye_pad[assign_slot.reshape(-1)].reshape(T, m.top_k, D)
        y = jnp.einsum("tkd,tk->td", picked, topw.astype(picked.dtype))
    else:
        # combine: weighted scatter-add back to token order
        y_flat = ye.reshape(m.n_experts * C, D) * combine_w[:, None].astype(ye.dtype)
        y = jnp.zeros((T + 1, D), ye.dtype).at[gather_idx].add(y_flat)[:T]
    y = y.reshape(B, S, D)
    y = partition.shard_act(y, "batch", "seq", None)

    if m.n_shared_experts:
        gate = jax.nn.sigmoid(
            jnp.einsum("bsd,dg->bsg", x, p["shared_gate"]).astype(jnp.float32)
        ).astype(x.dtype)
        y = y + gate * layers.swiglu(x, p["shared"])

    # Switch-style load-balance aux loss
    me = probs.mean(axis=0)                                  # mean router prob per expert
    one_hot_top1 = jax.nn.one_hot(topi[:, 0], m.n_experts, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=0)                           # fraction routed (top-1)
    aux = m.n_experts * jnp.sum(me * ce)
    return y, aux
