"""GQA attention blocks: init + train/prefill/decode/cross application.

All flavors funnel into kernels.flash_attention.ops (Pallas on TPU, jnp ref
elsewhere). Decode writes k/v into a caller-owned cache at position ``pos``
and attends over entries <= pos.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.flash_attention import ops as attn_ops
from ..sharding import partition
from . import layers


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    """Weights for one attention block. cross=True adds no rope and is
    initialized identically (separate weights for whisper cross-attn)."""
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = layers.dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "wq": layers.dense_init(k1, (D, H, hd), D, dt),
        "wk": layers.dense_init(k2, (D, KV, hd), D, dt),
        "wv": layers.dense_init(k3, (D, KV, hd), D, dt),
        "wo": layers.dense_init(k4, (H, hd, D), H * hd, dt),
    }
    specs = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv_heads", None),
        "wv": ("embed", "kv_heads", None),
        "wo": ("heads", None, "embed"),
    }
    if cfg.qkv_bias:
        params.update(
            bq=jnp.zeros((H, hd), dt), bk=jnp.zeros((KV, hd), dt), bv=jnp.zeros((KV, hd), dt)
        )
        specs.update(bq=("heads", None), bk=("kv_heads", None), bv=("kv_heads", None))
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((hd,), jnp.float32)
        params["k_norm"] = jnp.ones((hd,), jnp.float32)
        specs["q_norm"] = (None,)
        specs["k_norm"] = (None,)
    return params, specs


def _headwise_rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def _qkv(p, x, cfg: ModelConfig, positions: Optional[jnp.ndarray], rope: bool):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = _headwise_rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = _headwise_rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if rope and positions is not None:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def self_attention(
    p,
    x: jnp.ndarray,                       # (B, S, D)
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
    return_kv: bool = False,
) -> Tuple[jnp.ndarray, Optional[Tuple[jnp.ndarray, jnp.ndarray]]]:
    rope = cfg.rope_theta > 0
    q, k, v = _qkv(p, x, cfg, positions, rope)
    # context-parallel fallback: when heads don't divide the model axis, the
    # head compute replicates; sharding q's SEQUENCE over `model` instead
    # recovers 1/model of the attention flops (k/v are gathered — O(S·d)
    # traffic vs the O(S^2) compute win)
    q_seq = "seq_shard" if cfg.attn_seq_shard else "seq"
    q = partition.shard_act(q, "batch", q_seq, "heads", None)
    k = partition.shard_act(k, "batch", "seq", "kv_heads", None)
    v = partition.shard_act(v, "batch", "seq", "kv_heads", None)
    o = attn_ops.flash_attention(q, k, v, causal=causal)
    if cfg.attn_seq_shard:
        o = partition.shard_act(o, "batch", "seq_shard", "heads", None)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return (out, (k, v)) if return_kv else (out, None)


def self_attention_decode(
    p,
    x: jnp.ndarray,                       # (B, 1, D)
    k_cache: jnp.ndarray,                 # (B, S, KV, hd)
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,                     # scalar or (B,) int32: write position
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    rope = cfg.rope_theta > 0
    vec = pos.ndim == 1
    positions = (pos[:, None] if vec else pos[None]) if rope else None
    q, k, v = _qkv(p, x, cfg, positions, rope)
    if vec:  # per-sequence positions (continuous batching)
        rows = jnp.arange(k_cache.shape[0])
        k_cache = k_cache.at[rows, pos].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[rows, pos].set(v[:, 0].astype(v_cache.dtype))
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), pos, axis=1
        )
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), pos, axis=1
        )
    o = attn_ops.decode_attention(q, k_cache, v_cache, pos)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (k_cache, v_cache)


def cross_attention(
    p,
    x: jnp.ndarray,                       # (B, Sq, D) decoder states
    kv_source: Optional[jnp.ndarray] = None,   # (B, Skv, D) encoder output
    kv_cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    cfg: ModelConfig = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Whisper-style cross attention. Pass kv_source at prefill/train (k, v
    computed and returned for caching); pass kv_cache at decode."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg is not None and cfg.qkv_bias:
        q = q + p["bq"]
    if kv_cache is not None:
        k, v = kv_cache
    else:
        k = jnp.einsum("bsd,dhk->bshk", kv_source, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", kv_source, p["wv"])
        if cfg is not None and cfg.qkv_bias:
            k, v = k + p["bk"], v + p["bv"]
    o = attn_ops.flash_attention(q, k, v, causal=False)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (k, v)
