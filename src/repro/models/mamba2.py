"""Mamba2 block (SSD mixer + depthwise causal conv + gated norm).

Projections are kept as separate weights (wz/wx/wB/wC/wdt) instead of one
fused in_proj so each output shards cleanly: d_inner and dt-heads over
`model`, d_model over `data`. The conv runs over the concatenated [x, B, C]
channels as in the reference implementation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.ssd import ops as ssd_ops
from ..sharding import partition
from . import layers


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    H = s.n_heads(cfg.d_model)
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, H, conv_dim


def init_mamba2(key, cfg: ModelConfig):
    s, d_in, H, conv_dim = dims(cfg)
    D = cfg.d_model
    dt = layers.dtype_of(cfg)
    ks = jax.random.split(key, 8)
    gn = s.n_groups * s.d_state
    params = {
        "wz": layers.dense_init(ks[0], (D, d_in), D, dt),
        "wx": layers.dense_init(ks[1], (D, d_in), D, dt),
        "wB": layers.dense_init(ks[2], (D, gn), D, dt),
        "wC": layers.dense_init(ks[3], (D, gn), D, dt),
        "wdt": layers.dense_init(ks[4], (D, H), D, dt),
        "conv_w": (jax.random.normal(ks[5], (conv_dim, s.conv_kernel), jnp.float32)
                   * (s.conv_kernel ** -0.5)).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))),  # softplus^-1
        "norm": jnp.ones((d_in,), jnp.float32),
        "out_proj": layers.dense_init(ks[6], (d_in, D), d_in, dt),
    }
    specs = {
        "wz": ("embed", "ssm_inner"),
        "wx": ("embed", "ssm_inner"),
        "wB": ("embed", None),
        "wC": ("embed", None),
        "wdt": ("embed", "ssm_heads"),
        "conv_w": ("ssm_conv", None),
        "conv_b": ("ssm_conv",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }
    return params, specs


def _gated_norm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale).astype(y.dtype)


def _causal_depthwise_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """xbc: (B, S, Cd); w: (Cd, K). Causal: output[t] uses inputs [t-K+1, t]."""
    K = w.shape[-1]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[:, i][None, None, :] for i in range(K)
    )
    return out + b[None, None, :]


def mamba2_block(
    p,
    x: jnp.ndarray,                        # (B, S, D)
    cfg: ModelConfig,
    *,
    return_state: bool = False,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    s, d_in, H, conv_dim = dims(cfg)
    gn = s.n_groups * s.d_state
    B_, S, _ = x.shape

    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xin = jnp.einsum("bsd,de->bse", x, p["wx"])
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"])

    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)
    xbc = _causal_depthwise_conv(xbc, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xin, Bm, Cm = jnp.split(xbc, [d_in, d_in + gn], axis=-1)

    xh = xin.reshape(B_, S, H, s.head_dim)
    xh = partition.shard_act(xh, "batch", "seq", "ssm_heads", None)
    Bg = Bm.reshape(B_, S, s.n_groups, s.d_state)
    Cg = Cm.reshape(B_, S, s.n_groups, s.d_state)
    dt_act = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    # pad S to a chunk multiple; dt=0 at pads -> decay 1, contribution 0, so
    # outputs and the final state are unaffected
    chunk = min(s.chunk, S)
    pad = (-S) % chunk
    if pad:
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        xh, Bg, Cg, dt_act = zpad(xh), zpad(Bg), zpad(Cg), zpad(dt_act)
    y, final_state = ssd_ops.ssd(
        xh, dt_act, A, Bg, Cg, chunk=chunk, return_final_state=return_state
    )
    if pad:
        y, xh = y[:, :S], xh[:, :S]
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, S, d_in)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])

    state = None
    if return_state:
        # conv cache must hold the last K-1 *pre-activation inputs* to the conv
        # (i.e. the raw projections). Recompute them cheaply from the tail:
        raw_tail = jnp.concatenate(
            [
                jnp.einsum("bsd,de->bse", x[:, -(s.conv_kernel - 1):], p["wx"]),
                jnp.einsum("bsd,dn->bsn", x[:, -(s.conv_kernel - 1):], p["wB"]),
                jnp.einsum("bsd,dn->bsn", x[:, -(s.conv_kernel - 1):], p["wC"]),
            ],
            axis=-1,
        )
        state = {"conv": raw_tail, "ssm": final_state}
    return out, state


def mamba2_decode(
    p,
    x: jnp.ndarray,                        # (B, 1, D)
    state: dict,                           # {"conv": (B, K-1, Cd), "ssm": (B, H, P, N)}
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, dict]:
    s, d_in, H, conv_dim = dims(cfg)
    gn = s.n_groups * s.d_state
    B_ = x.shape[0]

    z = jnp.einsum("bsd,de->bse", x, p["wz"])[:, 0]
    xin = jnp.einsum("bsd,de->bse", x, p["wx"])[:, 0]
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"])[:, 0]
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"])[:, 0]
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"])[:, 0]

    raw = jnp.concatenate([xin, Bm, Cm], axis=-1)            # (B, Cd)
    window = jnp.concatenate([state["conv"], raw[:, None, :]], axis=1)  # (B, K, Cd)
    conv_out = jnp.einsum("bkc,ck->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xin, Bm, Cm = jnp.split(conv_out, [d_in, d_in + gn], axis=-1)

    xh = xin.reshape(B_, H, s.head_dim)
    Bg = Bm.reshape(B_, s.n_groups, s.d_state)
    Cg = Cm.reshape(B_, s.n_groups, s.d_state)
    dt_act = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, new_ssm = ssd_ops.ssd_decode(state["ssm"], xh, dt_act, A, Bg, Cg)
    y = y + xh * p["D"][None, :, None].astype(y.dtype)
    y = y.reshape(B_, d_in)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None, :]

    new_state = {"conv": window[:, 1:], "ssm": new_ssm.astype(state["ssm"].dtype)}
    return out, new_state


def init_decode_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Tuple[dict, dict]:
    """Zero state (+ logical specs) for one mamba2 layer."""
    s, d_in, H, conv_dim = dims(cfg)
    state = {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, conv_dim), layers.dtype_of(cfg)),
        "ssm": jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
    }
    specs = {
        "conv": ("batch", None, "ssm_conv"),
        "ssm": ("batch", "ssm_heads", None, None),
    }
    return state, specs
