"""Shared model primitives: norms, RoPE, positional encodings, MLPs, embeddings.

Every ``init_*`` returns ``(params, specs)`` where ``specs`` mirrors the param
pytree with tuples of *logical* axis names (resolved against the mesh by
``sharding.partition``). Compute follows the usual mixed-precision recipe:
bf16 weights/activations, fp32 norms/softmax/rope.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig

Params = dict
Specs = dict


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, fan_in: int, dtype) -> jnp.ndarray:
    scale = fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# -- norms ---------------------------------------------------------------
def init_rmsnorm(d: int) -> Tuple[Params, Specs]:
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": ("embed",)}


def rmsnorm(x: jnp.ndarray, p: Params, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


def init_layernorm(d: int) -> Tuple[Params, Specs]:
    return (
        {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)},
        {"scale": ("embed",), "bias": ("embed",)},
    )


def layernorm(x: jnp.ndarray, p: Params, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# -- rotary / sinusoidal positions ------------------------------------------
def rope_frequencies(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (S,) or (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                      # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed absolute positional embedding (n, d)."""
    half = d // 2
    log_timescale = jnp.log(10000.0) / max(half - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    scaled = jnp.arange(n, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=-1)


# -- MLPs -------------------------------------------------------------------
def init_swiglu(key, d: int, f: int, dtype) -> Tuple[Params, Specs]:
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "wi": dense_init(k1, (d, f), d, dtype),
        "wg": dense_init(k2, (d, f), d, dtype),
        "wo": dense_init(k3, (f, d), f, dtype),
    }
    specs = {"wi": ("embed", "mlp"), "wg": ("embed", "mlp"), "wo": ("mlp", "embed")}
    return params, specs


def swiglu(x: jnp.ndarray, p: Params) -> jnp.ndarray:
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    g = jnp.einsum("...d,df->...f", x, p["wg"])
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, p["wo"])


def init_gelu_mlp(key, d: int, f: int, dtype) -> Tuple[Params, Specs]:
    k1, k2 = jax.random.split(key)
    params = {
        "wi": dense_init(k1, (d, f), d, dtype),
        "bi": jnp.zeros((f,), dtype),
        "wo": dense_init(k2, (f, d), f, dtype),
        "bo": jnp.zeros((d,), dtype),
    }
    specs = {"wi": ("embed", "mlp"), "bi": ("mlp",), "wo": ("mlp", "embed"), "bo": ("embed",)}
    return params, specs


def gelu_mlp(x: jnp.ndarray, p: Params) -> jnp.ndarray:
    h = jnp.einsum("...d,df->...f", x, p["wi"]) + p["bi"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, p["wo"]) + p["bo"]


# -- embeddings ---------------------------------------------------------------
def init_embedding(key, vocab: int, d: int, dtype) -> Tuple[Params, Specs]:
    tok = (jax.random.normal(key, (vocab, d), jnp.float32) * d ** -0.5).astype(dtype)
    return {"tok": tok}, {"tok": ("vocab", "embed")}


def embed(tokens: jnp.ndarray, p: Params) -> jnp.ndarray:
    return jnp.take(p["tok"], tokens, axis=0)


def init_unembed(key, vocab: int, d: int, dtype) -> Tuple[Params, Specs]:
    w = dense_init(key, (d, vocab), d, dtype)
    return {"w": w}, {"w": ("embed", "vocab")}


def logits_from(h: jnp.ndarray, unembed_p: Optional[Params], embed_p: Params) -> jnp.ndarray:
    """fp32 logits; tied embeddings when no separate unembed is present."""
    if unembed_p is not None:
        return jnp.einsum("...d,dv->...v", h, unembed_p["w"]).astype(jnp.float32)
    return jnp.einsum("...d,vd->...v", h, embed_p["tok"]).astype(jnp.float32)


def cross_entropy_loss(
    logits: jnp.ndarray,        # (B, S, V) fp32
    targets: jnp.ndarray,       # (B, S) int32
    mask: Optional[jnp.ndarray] = None,  # (B, S) 1.0 where counted
) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is None:
        return nll.mean()
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / denom
