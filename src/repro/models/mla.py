"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3).

Prefill expands the compressed latent into per-head k/v; decode runs the
*absorbed* form: queries are projected into latent space and attention runs
as MQA with a single (kv_lora + rope)-wide kv head — the cache stores only
(c_kv, k_rope) per token, the technique's memory advantage.
"""
from __future__ import annotations

from typing import Optional


import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.flash_attention import ops as attn_ops
from ..sharding import partition
from . import layers


def init_mla(key, cfg: ModelConfig):
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    dt = layers.dtype_of(cfg)
    ks = jax.random.split(key, 7)
    params = {
        "wdq": layers.dense_init(ks[0], (D, m.q_lora_rank), D, dt),
        "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "wuq": layers.dense_init(ks[1], (m.q_lora_rank, H, qk), m.q_lora_rank, dt),
        "wdkv": layers.dense_init(ks[2], (D, m.kv_lora_rank), D, dt),
        "wkr": layers.dense_init(ks[3], (D, m.qk_rope_dim), D, dt),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "wuk": layers.dense_init(ks[4], (m.kv_lora_rank, H, m.qk_nope_dim), m.kv_lora_rank, dt),
        "wuv": layers.dense_init(ks[5], (m.kv_lora_rank, H, m.v_head_dim), m.kv_lora_rank, dt),
        "wo": layers.dense_init(ks[6], (H, m.v_head_dim, D), H * m.v_head_dim, dt),
    }
    specs = {
        "wdq": ("embed", "latent"),
        "q_norm": (None,),
        "wuq": ("latent", "heads", None),
        "wdkv": ("embed", "latent"),
        "wkr": ("embed", None),
        "kv_norm": (None,),
        "wuk": ("latent", "heads", None),
        "wuv": ("latent", "heads", None),
        "wo": ("heads", None, "embed"),
    }
    return params, specs


def _norm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def _queries(p, x, cfg, positions):
    m = cfg.mla
    ql = _norm(jnp.einsum("bsd,dr->bsr", x, p["wdq"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", ql, p["wuq"])
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    if positions is not None:
        q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent_kv(p, x, cfg, positions):
    c_kv = _norm(jnp.einsum("bsd,dr->bsr", x, p["wdkv"]), p["kv_norm"], cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["wkr"])
    if positions is not None:
        k_rope = layers.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_attention(
    p,
    x: jnp.ndarray,                        # (B, S, D)
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    return_cache: bool = False,
):
    """Prefill/train path: expand latent to per-head k/v, causal attention."""
    m = cfg.mla
    q_nope, q_rope = _queries(p, x, cfg, positions)
    c_kv, k_rope = _latent_kv(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wuk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wuv"])
    H = cfg.n_heads
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (*k_rope.shape[:2], H, m.qk_rope_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q_seq = "seq_shard" if cfg.attn_seq_shard else "seq"
    q = partition.shard_act(q, "batch", q_seq, "heads", None)
    o = attn_ops.flash_attention(q, k, v, causal=True, scale=(m.qk_nope_dim + m.qk_rope_dim) ** -0.5)
    if cfg.attn_seq_shard:
        o = partition.shard_act(o, "batch", "seq_shard", "heads", None)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return (out, (c_kv, k_rope)) if return_cache else (out, None)


def mla_attention_decode(
    p,
    x: jnp.ndarray,                       # (B, 1, D)
    ckv_cache: jnp.ndarray,               # (B, S, kv_lora)
    krope_cache: jnp.ndarray,             # (B, S, rope_dim)
    pos: jnp.ndarray,
    cfg: ModelConfig,
):
    """Absorbed decode: MQA over the compressed cache."""
    m = cfg.mla
    vec = pos.ndim == 1
    positions = pos[:, None] if vec else pos[None]
    q_nope, q_rope = _queries(p, x, cfg, positions=positions)
    c_kv, k_rope = _latent_kv(p, x, cfg, positions=positions)
    if vec:
        rows = jnp.arange(ckv_cache.shape[0])
        ckv_cache = ckv_cache.at[rows, pos].set(c_kv[:, 0].astype(ckv_cache.dtype))
        krope_cache = krope_cache.at[rows, pos].set(k_rope[:, 0].astype(krope_cache.dtype))
    else:
        ckv_cache = jax.lax.dynamic_update_slice_in_dim(
            ckv_cache, c_kv.astype(ckv_cache.dtype), pos, axis=1
        )
        krope_cache = jax.lax.dynamic_update_slice_in_dim(
            krope_cache, k_rope.astype(krope_cache.dtype), pos, axis=1
        )
    # absorb W_uk into the query: q_lat (B, 1, H, kv_lora)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"])
    q_full = jnp.concatenate([q_lat, q_rope], axis=-1)              # (B,1,H,lora+rope)
    k_full = jnp.concatenate([ckv_cache, krope_cache], axis=-1)[:, :, None, :]  # (B,S,1,·)
    v_lat = ckv_cache[:, :, None, :]                                 # (B,S,1,lora)
    o_lat = attn_ops.decode_attention(
        q_full, k_full, v_lat, pos, scale=(m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    )                                                                # (B,1,H,lora)
    o = jnp.einsum("bshr,rhk->bshk", o_lat, p["wuv"])                # absorb W_uv
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (ckv_cache, krope_cache)
