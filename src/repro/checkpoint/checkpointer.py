"""Checkpoint/restart: sharded save + async write + reshard-on-restore.

The fault-tolerance story at pod scale: the FaaS layer re-executes lost step
functions (transient failures), and the training loop periodically calls
``save`` so a lost *manager/controller* restarts from the newest manifest
(``latest_step``). Restoring onto a different mesh is supported because
arrays are stored unsharded per-leaf and re-placed with the target shardings
(elastic re-scale: 512 -> 256 chips just changes the shardings).

Layout:  <dir>/step_<N>/manifest.msgpack  (+ one .npy per leaf)
"""
from __future__ import annotations

import os
import shutil
import threading
import time
from typing import Any, List, Optional, Tuple



import jax
import numpy as np

from ..core import serializer


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> str:
        """Snapshot `tree` at `step`. Device arrays are fetched to host first
        (cheap vs. the async write); the write itself runs on a thread."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        path = os.path.join(self.directory, f"step_{step:08d}")

        def _write():
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            leaves = _flatten_with_paths(host_tree)
            manifest = {"step": step, "leaves": [], "time": time.time()}
            for i, (key, leaf) in enumerate(leaves):
                fname = f"leaf_{i:05d}.npy"
                np.save(os.path.join(tmp, fname), leaf)
                manifest["leaves"].append(
                    {"key": key, "file": fname, "shape": list(leaf.shape), "dtype": str(leaf.dtype)}
                )
            with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
                f.write(serializer.packb(manifest))
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)
            self._gc()

        self.wait()  # at most one in-flight save
        if self.async_save and not blocking:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
        return path

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def list_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[int, Any]:
        """Restore into the structure of `like`. With `shardings` (a pytree of
        NamedSharding matching `like`), leaves are placed sharded — this is
        the elastic-rescale path."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
            manifest = serializer.unpackb(f.read())
        arrays = [
            np.load(os.path.join(path, leaf["file"])) for leaf in manifest["leaves"]
        ]
        treedef = jax.tree.structure(like)
        if treedef.num_leaves != len(arrays):
            raise ValueError(
                f"checkpoint has {len(arrays)} leaves; template has {treedef.num_leaves}"
            )
        tree = jax.tree.unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
        return step, tree
