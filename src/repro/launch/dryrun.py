import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
#
# The two lines above MUST run before any jax import (jax locks the device
# count on first init); they are deliberately NOT in conftest/pyproject so
# tests and benches see 1 device.
__doc__ = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Usage:
    python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
    python -m repro.launch.dryrun --all                    # every live cell
    python -m repro.launch.dryrun --all --multi-pod        # 2x16x16 mesh
    python -m repro.launch.dryrun --arch X --shape Y --override remat_policy=dots

Results accumulate in benchmarks/results/dryrun.json keyed by
(arch|shape|mesh|overrides) so reruns are incremental; --force recomputes.
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs import ARCH_IDS, SHAPES, cell_applicable, get_config
from repro.launch import analysis
from repro.launch.mesh import describe, make_mesh, make_production_mesh
from repro.models.model import Model
from repro.sharding import partition
from repro.training import optimizer as opt
from repro.training import steps as steps_mod

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "../../../benchmarks/results/dryrun.json")


def _parse_overrides(pairs) -> dict:
    out = {}
    for pair in pairs or ():
        k, v = pair.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("true", "True"):
            v = True
        if v in ("false", "False"):
            v = False
        out[k] = v
    return out


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D (dense) / 6·N_active·D (MoE); D = tokens processed.
    For decode steps D = global_batch (one token each); for train, the 3x
    factor for bwd is included by the 6 (2 fwd + 4 bwd); prefill/decode use
    2·N·D (forward only)."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def build_cell(arch: str, shape_name: str, mesh, overrides: Optional[dict] = None):
    cfg = get_config(arch)
    overrides = dict(overrides or {})
    # optimizer-level overrides travel with an "opt_" prefix
    opt_kwargs = {k[4:]: overrides.pop(k) for k in list(overrides) if k.startswith("opt_")}
    if overrides:
        cfg = cfg.with_(**overrides)
    shape = SHAPES[shape_name]
    model = Model(cfg)
    with partition.use_mesh(mesh, rules=partition.rules_for(cfg)):
        if shape.kind == "train":
            built = steps_mod.build_train_step(
                model, opt.OptimizerConfig(**opt_kwargs), mesh, shape)
        elif shape.kind == "prefill":
            built = steps_mod.build_prefill_step(model, mesh, shape)
        else:
            built = steps_mod.build_decode_step(model, mesh, shape)
    return cfg, shape, built


def _compile_cell(arch, shape_name, mesh, overrides):
    cfg, shape, built = build_cell(arch, shape_name, mesh, overrides)
    jitted = jax.jit(
        built.fn,
        in_shardings=built.in_shardings,
        out_shardings=built.out_shardings,
        donate_argnums=built.donate_argnums,
    )
    t0 = time.monotonic()
    with partition.use_mesh(mesh, rules=partition.rules_for(cfg)):
        lowered = jitted.lower(*built.abstract_args)
        t1 = time.monotonic()
        compiled = lowered.compile()
    t2 = time.monotonic()
    return cfg, shape, compiled, round(t1 - t0, 2), round(t2 - t1, 2)


def _calibration_depths(cfg) -> tuple:
    """(L1, L2, units): unrolled calibration compiles at depths L1 < L2; the
    true per-repeat-unit cost is (cost(L2)-cost(L1))/(units(L2)-units(L1)).
    XLA's cost analysis counts a lax.scan body ONCE regardless of trip count,
    so the production (scanned) compile proves compilability + memory, while
    two shallow UNROLLED compiles recover the true flops/bytes/collectives:
        total = base(L1) + (units-1) * delta.
    Exact for layer-homogeneous stacks (all assigned archs)."""
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        return k, 2 * k, cfg.n_layers // k
    return 1, 2, cfg.n_layers


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             mesh_spec: Optional[str] = None, overrides: Optional[dict] = None,
             verbose: bool = True, calibrate: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": reason}

    if mesh_spec:  # e.g. "2,4" for tests
        dims = tuple(int(x) for x in mesh_spec.split(","))
        names = ("pod", "data", "model")[-len(dims):]
        mesh = make_mesh(dims, names)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v

    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": describe(mesh),
        "overrides": overrides or {}, "status": "ok",
    }
    try:
        # 1) production compile (scan over layers): compile proof + memory truth
        cfg2, shape2, compiled, lower_s, compile_s = _compile_cell(
            arch, shape_name, mesh, overrides
        )
        record["lower_s"] = lower_s
        record["compile_s"] = compile_s
        mflops = model_flops(cfg2, shape2)
        record["analysis"] = analysis.analyze_compiled(compiled, n_chips, mflops)

        # 2) calibration compiles (unrolled, shallow) -> true static costs
        if calibrate:
            L1, L2, units = _calibration_depths(cfg2)
            cal_costs = []
            for depth in (L1, L2):
                ov = dict(overrides or {})
                # microbatches=1: the microbatch lax.scan would hide M-1 of
                # the work from cost analysis exactly like the layer scan;
                # total per-step cost is M-invariant for a fixed global batch
                ov.update(n_layers=depth, scan_layers=False, microbatches=1)
                if cfg2.family == "encdec":
                    ov.setdefault("n_enc_layers", cfg2.n_enc_layers)
                _, _, c, _, _ = _compile_cell(arch, shape_name, mesh, ov)
                cal_costs.append(analysis.extract_costs(c))
            total = analysis.extrapolate(cal_costs[0], cal_costs[1], units)
            record["analysis"]["calibrated"] = total
            mm = analysis.modeled_hbm_bytes(
                cfg2, shape2, n_chips, model_axis=mesh.shape.get("model", 1)
            )
            record["analysis"]["modeled_memory"] = mm
            # roofline: compute+collective measured (calibrated); memory term
            # from the TPU-fused model (raw unfused bytes kept as upper bound)
            record["analysis"]["roofline"] = analysis.roofline_terms(
                total["flops_per_device"], mm["total"],
                total["wire_bytes_per_device"], model_flops_total=mflops,
                n_chips=n_chips,
            )
            record["analysis"]["roofline"]["memory_unfused_upper_bound_s"] = (
                total["hbm_bytes_per_device"] / analysis.HW["hbm_bw"]
            )
            record["analysis"]["roofline"]["source"] = (
                f"calibrated unrolled L={L1},{L2} -> units={units}; "
                "memory term modeled (TPU-fused, flash-attn)"
            )
        if verbose:
            a = record["analysis"]
            r = a["roofline"]
            print(
                f"[{arch} x {shape_name} x {n_chips}ch] "
                f"resident={a['memory']['resident_gib']}GiB fits={a['memory']['fits_hbm']} "
                f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                f"collective={r['collective_s']:.4f}s -> {r['bottleneck']} "
                f"(roofline_frac={r.get('roofline_fraction', 0):.3f}) "
                f"[lower {record['lower_s']}s compile {record['compile_s']}s]",
                flush=True,
            )
    except Exception as e:  # noqa: BLE001
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc(limit=10)
        if verbose:
            print(f"[{arch} x {shape_name}] FAILED: {record['error']}", flush=True)
    return record


def _key(arch, shape, multi_pod, overrides) -> str:
    ov = ",".join(f"{k}={v}" for k, v in sorted((overrides or {}).items()))
    return f"{arch}|{shape}|{'multipod' if multi_pod else 'singlepod'}|{ov}"


def load_results(path: str = RESULTS_PATH) -> dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(results: dict, path: str = RESULTS_PATH) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true", help="run every live cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mesh", help="explicit mesh dims for tests, e.g. 2,4")
    ap.add_argument("--override", action="append", help="cfg field=value")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--results", default=RESULTS_PATH)
    args = ap.parse_args()

    overrides = _parse_overrides(args.override)
    results = load_results(args.results)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    for multi_pod in meshes:
        for arch, shape in cells:
            key = _key(arch, shape, multi_pod, overrides)
            if key in results and not args.force and results[key].get("status") != "error":
                print(f"[cached] {key}", flush=True)
                continue
            rec = run_cell(arch, shape, multi_pod=multi_pod, mesh_spec=args.mesh,
                           overrides=overrides)
            results[key] = rec
            save_results(results, args.results)
            if rec["status"] == "error":
                failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
