"""Serving driver: continuous-batching LM inference behind the FaaS service.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \\
        --requests 16 --max-new-tokens 12

Requests enter as registered-function invocations (`generate`), the engine
packs them into shared-cache decode batches, and the run reports TTFT and
aggregate token throughput. On this container the reduced config runs; on a
pod the full config serves under the decode_32k sharding proven by the
dry-run.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.core import FunctionService
from repro.models.model import Model
from repro.serving.engine import ServeEngine


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    args = ap.parse_args()

    cfg = (get_reduced(args.arch) if args.reduced else get_config(args.arch)).with_(
        dtype="float32" if args.reduced else "bfloat16"
    )
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_batch=args.max_batch, max_len=args.max_len)

    # the FaaS front door: a registered function that enqueues into the engine
    service = FunctionService()
    service.make_endpoint("serve-frontdoor", n_executors=1, workers_per_executor=2)

    def generate(doc):
        req = engine.submit(doc["prompt"], max_new_tokens=doc.get("max_new_tokens", 8))
        if not req.done.wait(timeout=600):
            raise TimeoutError(req.request_id)
        return {"tokens": np.asarray(req.tokens, np.int32),
                "ttft_ms": (req.first_token_at - req.submitted) * 1e3}

    fid = service.register_function(generate, name=f"generate/{cfg.name}",
                                    pass_through=True, serialize_result=False,
                                    deterministic=False)

    import threading

    stop = threading.Event()
    loop = threading.Thread(target=engine.serve_forever, args=(stop,), daemon=True)
    loop.start()

    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    futs = [
        service.run(fid, {"prompt": rng.integers(0, cfg.vocab, int(rng.integers(4, 12))),
                          "max_new_tokens": args.max_new_tokens})
        for _ in range(args.requests)
    ]
    outs = [f.result(600) for f in futs]
    stop.set()
    loop.join(timeout=5)
    wall = time.monotonic() - t0
    total = sum(len(o["tokens"]) for o in outs)
    ttfts = [o["ttft_ms"] for o in outs]
    print(f"{cfg.name}: {len(outs)} requests / {total} tokens in {wall:.2f}s "
          f"({total/wall:.1f} tok/s); TTFT mean {np.mean(ttfts):.1f}ms "
          f"p95 {np.percentile(ttfts, 95):.1f}ms")
    service.shutdown()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
