"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips
(TPU v5e-256-class). Multi-pod: a leading pod axis, (pod=2, data=16,
model=16) = 512 chips; batch dims shard jointly over ("pod", "data").
"""
from __future__ import annotations

from typing import Sequence



import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """jax.make_mesh needs exactly prod(shape) devices; when the runtime has
    more (e.g. 512 forced host devices but a 256-chip single-pod mesh), build
    the Mesh from the first prod(shape) devices directly."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(tuple(shape), tuple(axes))
    if len(devices) < n:
        raise ValueError(f"need {n} devices for mesh {tuple(shape)}, have {len(devices)}")
    arr = np.array(devices[:n]).reshape(tuple(shape))
    return jax.sharding.Mesh(arr, tuple(axes))


def describe(mesh) -> dict:
    return {
        "axes": dict(mesh.shape),
        "devices": int(np.prod(list(mesh.shape.values()))),
        "platform": jax.devices()[0].platform,
    }
