"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \\
        --reduced --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt

On this CPU container use --reduced (the smoke config of the same family);
on a real pod omit it and pass --mesh-from-env. Steps run as registered FaaS
functions on a local endpoint (routing + warming + retry + telemetry), the
checkpointer bounds restart loss, and the data pipeline prefetches.
"""
from __future__ import annotations

import argparse
import json


from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.core import FunctionService
from repro.models.model import Model
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import TrainConfig, Trainer


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--no-faas", action="store_true", help="run steps inline")
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = Model(cfg)
    ocfg = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                           total_steps=args.steps)
    tcfg = TrainConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                       ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt)

    service = None
    if not args.no_faas:
        service = FunctionService()
        service.make_endpoint("train-endpoint", n_executors=1, workers_per_executor=1)

    trainer = Trainer(model, ocfg, tcfg, service=service)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens", flush=True)
    history = trainer.run()
    if service is not None:
        service.shutdown()
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} over {len(history)} steps")
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(history, f, indent=1)
    return 0 if last < first else 2


if __name__ == "__main__":
    raise SystemExit(main())
