"""Roofline-term extraction from compiled dry-run artifacts.

Three terms, all in per-chip seconds (cost_analysis of an SPMD-partitioned
module reports PER-DEVICE flops/bytes — verified empirically):

    compute    = flops_per_device / peak_flops
    memory     = hbm_bytes_per_device / hbm_bw
    collective = wire_bytes_per_device / ici_bw

collective bytes are NOT in cost_analysis: we parse the optimized HLO
(compiled.as_text()) and sum per-op wire traffic with ring-algorithm factors:
    all-reduce      2·S·(n-1)/n      (reduce-scatter + all-gather phases)
    all-gather      R·(n-1)/n        (R = result bytes)
    reduce-scatter  R·(n-1)          (input = n·R; each device moves (n-1)·R)
    all-to-all      R·(n-1)/n
    collective-permute  R
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# TPU v5e-class constants (per chip)
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s effective per chip (≈1 link busy)
    "hbm_bytes": 16 * 2**30,     # capacity
}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_OP_RE = re.compile(
    r"=\s*(?P<result>.*?)\s+(?P<op>all-reduce-start|all-gather-start|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute|"
    r"all-reduce|all-gather)\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _shape_bytes(result: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(result):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [t for t in m.group(1).split(",") if t.strip()]
        return max(len(ids), 1)
    return 1


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    result_bytes: Dict[str, int] = field(default_factory=dict)
    wire_bytes: Dict[str, float] = field(default_factory=dict)

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def to_dict(self) -> dict:
        return {
            "counts": dict(self.counts),
            "result_bytes": dict(self.result_bytes),
            "wire_bytes": {k: int(v) for k, v in self.wire_bytes.items()},
            "total_wire_bytes": int(self.total_wire_bytes),
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        m = _OP_RE.search(line)
        if m is None:
            continue
        op = m.group("op").replace("-start", "")
        rbytes = _shape_bytes(m.group("result"))
        n = _group_size(line)
        if n <= 1:
            continue  # single-participant: no wire traffic
        if op == "all-reduce":
            wire = 2 * rbytes * (n - 1) / n
        elif op == "all-gather":
            wire = rbytes * (n - 1) / n
        elif op == "reduce-scatter":
            wire = rbytes * (n - 1)
        elif op == "all-to-all":
            wire = rbytes * (n - 1) / n
        else:  # collective-permute
            wire = rbytes
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.result_bytes[op] = stats.result_bytes.get(op, 0) + rbytes
        stats.wire_bytes[op] = stats.wire_bytes.get(op, 0) + wire
    return stats


def modeled_hbm_bytes(cfg, shape, n_chips: int, model_axis: int = 16) -> dict:
    """Analytic per-device HBM traffic for the TPU-fused execution (flash
    attention keeps S^2 scores in VMEM; fusions keep elementwise chains out
    of HBM). The XLA-CPU 'bytes accessed' is reported alongside as the
    unfused upper bound — on CPU every materialized S^2 score tensor counts,
    which the TPU target never writes.

    Terms (documented coarse constants):
      params  train: 8x bf16 param bytes (fwd read, bwd read, remat read,
              grad write) + 24x fp32-equivalent optimizer r/w + 2x write-back
              prefill/decode: one bf16 read
      acts    per layer: residual/proj I/O ~8 D-wide + 4 F-wide passes per
              token, x3 for train (fwd+remat+bwd), x1 inference
      attn    flash traffic: q,k,v,o only (+cache r/w at decode)
    """
    N_loc = cfg.param_count() / n_chips
    data_total = max(n_chips // model_axis, 1)
    bpe = 2  # bf16

    if shape.kind == "train":
        param_traffic = (4 * 2 + 24 + 2) * N_loc  # ~34 bytes/param/step
        tokens_loc = shape.global_batch * shape.seq_len / data_total
        passes = 3
    elif shape.kind == "prefill":
        param_traffic = 2 * N_loc
        tokens_loc = shape.global_batch * shape.seq_len / data_total
        passes = 1
    else:  # decode
        param_traffic = 2 * N_loc
        tokens_loc = shape.global_batch / data_total
        passes = 1

    D = cfg.d_model
    if cfg.family == "moe":
        F_eff = cfg.moe.top_k * cfg.moe.d_ff_expert + (
            cfg.moe.d_ff_shared if cfg.moe.n_shared_experts else 0
        )
    elif cfg.family in ("ssm", "hybrid"):
        F_eff = 2 * cfg.ssm.d_inner(D)
    else:
        F_eff = cfg.d_ff
    act_per_layer = tokens_loc * (8 * D + 4 * F_eff / max(model_axis, 1)) * bpe
    act_traffic = cfg.n_layers * act_per_layer * passes

    cache_traffic = 0.0
    if shape.kind == "decode":
        from ..serving.kv_cache import cache_bytes

        cache_traffic = 2.0 * cache_bytes(cfg, shape.global_batch, shape.seq_len) / n_chips

    total = param_traffic + act_traffic + cache_traffic
    return {
        "total": float(total),
        "param_traffic": float(param_traffic),
        "act_traffic": float(act_traffic),
        "cache_traffic": float(cache_traffic),
    }


def roofline_terms(
    flops_per_device: float,
    hbm_bytes_per_device: float,
    wire_bytes_per_device: float,
    model_flops_total: Optional[float] = None,
    n_chips: int = 256,
) -> dict:
    t_compute = flops_per_device / HW["peak_flops_bf16"]
    t_memory = hbm_bytes_per_device / HW["hbm_bw"]
    t_collective = wire_bytes_per_device / HW["ici_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_collective}
    bottleneck = max(terms, key=terms.get)
    out = {
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "step_time_lower_bound_s": max(terms.values()),
    }
    if model_flops_total is not None:
        hlo_total = flops_per_device * n_chips
        out["model_flops_total"] = model_flops_total
        out["useful_flops_ratio"] = model_flops_total / hlo_total if hlo_total else 0.0
        # roofline fraction: useful model FLOPs per second at the bound step
        # time, relative to the fleet's peak
        t = out["step_time_lower_bound_s"]
        out["roofline_fraction"] = (
            model_flops_total / t / (n_chips * HW["peak_flops_bf16"]) if t > 0 else 0.0
        )
    return out


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a flat dict on recent jax but a
    one-element list of dicts on jax<=0.4.x; normalize to a dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def extract_costs(compiled) -> dict:
    """Static per-device costs of one compiled module (flops / HBM bytes /
    collective wire bytes)."""
    cost = _cost_dict(compiled)
    colls = parse_collectives(compiled.as_text())
    return {
        "flops_per_device": float(cost.get("flops", 0.0)),
        "hbm_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "wire_bytes_per_device": float(colls.total_wire_bytes),
        "collectives": colls.to_dict(),
    }


def extrapolate(base: dict, two_units: dict, units: int) -> dict:
    """Depth calibration: cost(L) = cost(L1) + (units-1) * (cost(L2)-cost(L1)).
    Exact for layer-homogeneous stacks; recovers what XLA's cost analysis
    hides inside lax.scan bodies (counted once regardless of trip count)."""
    out = {}
    for k in ("flops_per_device", "hbm_bytes_per_device", "wire_bytes_per_device"):
        delta = two_units[k] - base[k]
        out[k] = base[k] + (units - 1) * delta
        out[k + "_per_layer"] = delta
    out["collectives_base"] = base["collectives"]
    out["collectives_delta"] = two_units["collectives"]
    out["units"] = units
    return out


def analyze_compiled(compiled, n_chips: int, model_flops_total: Optional[float] = None) -> dict:
    mem = compiled.memory_analysis()
    # jax<=0.4.x CompiledMemoryStats lacks peak_memory_in_bytes; temp size is
    # the closest stand-in (peak transient allocation of the module)
    peak = getattr(mem, "peak_memory_in_bytes", None)
    if peak is None:
        peak = mem.temp_size_in_bytes
    cost = _cost_dict(compiled)
    colls = parse_collectives(compiled.as_text())
    flops = float(cost.get("flops", 0.0))
    hbm_bytes = float(cost.get("bytes accessed", 0.0))
    terms = roofline_terms(
        flops, hbm_bytes, colls.total_wire_bytes,
        model_flops_total=model_flops_total, n_chips=n_chips,
    )
    return {
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": peak,
            # XLA 'peak' excludes arguments; resident = args (weights/caches,
            # donated buffers alias into outputs) + peak temps
            "resident_bytes": mem.argument_size_in_bytes + peak,
            "resident_gib": round(
                (mem.argument_size_in_bytes + peak) / 2**30, 3
            ),
            "fits_hbm": bool(
                mem.argument_size_in_bytes + peak <= HW["hbm_bytes"]
            ),
        },
        "cost": {
            "flops_per_device": flops,
            "hbm_bytes_per_device": hbm_bytes,
        },
        "collectives": colls.to_dict(),
        "roofline": terms,
    }
