"""Payload serialization: pytree <-> bytes.

funcX exchanges JSON documents; our functions exchange array pytrees, so the
wire format is msgpack with a numpy extension type. The serializer is also the
basis for memoization keys (``payload_hash``): packing is canonical (dict keys
sorted) so equal payloads hash equally.
"""
from __future__ import annotations

import hashlib
from typing import Any

import msgpack
import numpy as np

_EXT_NDARRAY = 1
_EXT_TUPLE = 2
_EXT_SET = 3
_EXT_COMPLEX = 4


def _default(obj: Any):
    # jax.Array and anything array-like -> ndarray ext
    if hasattr(obj, "__array__") or isinstance(obj, np.ndarray):
        arr = np.asarray(obj)
        header = msgpack.packb((arr.dtype.str, arr.shape), use_bin_type=True)
        if arr.dtype == object:
            raise TypeError("object arrays are not serializable")
        body = arr.tobytes(order="C")
        return msgpack.ExtType(_EXT_NDARRAY, header + body)
    if isinstance(obj, tuple):
        return msgpack.ExtType(_EXT_TUPLE, packb(list(obj)))
    if isinstance(obj, (set, frozenset)):
        return msgpack.ExtType(_EXT_SET, packb(sorted(obj, key=repr)))
    if isinstance(obj, complex):
        return msgpack.ExtType(_EXT_COMPLEX, packb([obj.real, obj.imag]))
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    raise TypeError(f"cannot serialize {type(obj)!r}")


def _ext_hook(code: int, data: bytes):
    if code == _EXT_NDARRAY:
        unpacker = msgpack.Unpacker(use_list=True, raw=False)
        unpacker.feed(data)
        dtype_str, shape = unpacker.unpack()
        offset = unpacker.tell()
        # copy out of the wire bytes: a frombuffer view would be read-only,
        # and functions mutate their inputs freely (one copy, not a
        # slice-then-bytearray double copy)
        arr = np.frombuffer(data, dtype=np.dtype(dtype_str), offset=offset)
        return arr.reshape(shape).copy()
    if code == _EXT_TUPLE:
        return tuple(unpackb(data))
    if code == _EXT_SET:
        return set(unpackb(data))
    if code == _EXT_COMPLEX:
        re, im = unpackb(data)
        return complex(re, im)
    return msgpack.ExtType(code, data)


def _canonicalize(obj: Any) -> Any:
    """Sort dict keys recursively so packing is deterministic."""
    if isinstance(obj, dict):
        return {k: _canonicalize(obj[k]) for k in sorted(obj, key=repr)}
    if isinstance(obj, (list, tuple)):
        typ = type(obj)
        out = [_canonicalize(v) for v in obj]
        return typ(out) if typ is tuple else out
    return obj


def packb(obj: Any) -> bytes:
    return msgpack.packb(_canonicalize(obj), default=_default, use_bin_type=True)


def unpackb(data: bytes) -> Any:
    return msgpack.unpackb(data, ext_hook=_ext_hook, raw=False, strict_map_key=False)


def payload_hash(obj: Any) -> str:
    """Canonical content hash of a payload (memoization key component)."""
    return hashlib.sha256(packb(obj)).hexdigest()
