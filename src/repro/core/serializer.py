"""Payload serialization: pytree <-> bytes.

funcX exchanges JSON documents; our functions exchange array pytrees, so the
wire format is msgpack with a numpy extension type. The serializer is also the
basis for memoization keys (``payload_hash``): packing is canonical (dict keys
sorted) so equal payloads hash equally.
"""
from __future__ import annotations

import hashlib
from typing import Any

import msgpack
import numpy as np

_EXT_NDARRAY = 1
_EXT_TUPLE = 2
_EXT_SET = 3
_EXT_COMPLEX = 4
_EXT_DATAREF = 5

# Lazily bound: datastore imports this module at load time, so the reverse
# edge resolves on first use instead of at import.
_DataRef = None


def _dataref_type():
    global _DataRef
    if _DataRef is None:
        from .datastore import DataRef

        _DataRef = DataRef
    return _DataRef


def _default(obj: Any):
    DataRef = _dataref_type()
    if isinstance(obj, DataRef):
        # refs travel the wire as (key, size, locations); payload_hash uses a
        # location-free view so moving data never changes a memo key
        return msgpack.ExtType(
            _EXT_DATAREF, packb([obj.key, obj.size, list(obj.locations)])
        )
    # jax.Array and anything array-like -> ndarray ext
    if hasattr(obj, "__array__") or isinstance(obj, np.ndarray):
        arr = np.asarray(obj)
        header = msgpack.packb((arr.dtype.str, arr.shape), use_bin_type=True)
        if arr.dtype == object:
            raise TypeError("object arrays are not serializable")
        body = arr.tobytes(order="C")
        return msgpack.ExtType(_EXT_NDARRAY, header + body)
    if isinstance(obj, tuple):
        return msgpack.ExtType(_EXT_TUPLE, packb(list(obj)))
    if isinstance(obj, (set, frozenset)):
        return msgpack.ExtType(_EXT_SET, packb(sorted(obj, key=repr)))
    if isinstance(obj, complex):
        return msgpack.ExtType(_EXT_COMPLEX, packb([obj.real, obj.imag]))
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    raise TypeError(f"cannot serialize {type(obj)!r}")


def _ext_hook(code: int, data: bytes, writable: bool = True):
    if code == _EXT_NDARRAY:
        unpacker = msgpack.Unpacker(use_list=True, raw=False)
        unpacker.feed(data)
        dtype_str, shape = unpacker.unpack()
        offset = unpacker.tell()
        arr = np.frombuffer(data, dtype=np.dtype(dtype_str), offset=offset)
        arr = arr.reshape(shape)
        if not writable:
            # zero-copy fast path: a read-only view straight over the wire
            # bytes. Callers that never hand the array to user code (decoded
            # object caches, ref scans, unpack-to-repack hops) skip the copy
            # entirely — at million-task scale the unpack copy dominated the
            # decode hot path.
            return arr
        # copy out of the wire bytes: a frombuffer view would be read-only,
        # and functions mutate their inputs freely (one copy, not a
        # slice-then-bytearray double copy)
        return arr.copy()
    if code == _EXT_TUPLE:
        return tuple(unpackb(data, writable=writable))
    if code == _EXT_SET:
        return set(unpackb(data, writable=writable))
    if code == _EXT_COMPLEX:
        re, im = unpackb(data)
        return complex(re, im)
    if code == _EXT_DATAREF:
        key, size, locations = unpackb(data)
        return _dataref_type()(key=key, size=size, locations=tuple(locations))
    return msgpack.ExtType(code, data)


def _canonicalize(obj: Any) -> Any:
    """Sort dict keys recursively so packing is deterministic."""
    if isinstance(obj, dict):
        return {k: _canonicalize(obj[k]) for k in sorted(obj, key=repr)}
    if isinstance(obj, (list, tuple)):
        typ = type(obj)
        out = [_canonicalize(v) for v in obj]
        return typ(out) if typ is tuple else out
    return obj


def packb(obj: Any) -> bytes:
    return msgpack.packb(_canonicalize(obj), default=_default, use_bin_type=True)


def unpackb(data: bytes, writable: bool = True) -> Any:
    """Decode wire bytes back to a pytree.

    ``writable=True`` (the default API) copies array leaves out of the wire
    buffer so callers can mutate them. ``writable=False`` is the zero-copy
    fast path: array leaves are read-only ``frombuffer`` views over ``data``
    — use it only where the decoded value is never handed to user code (the
    endpoint decoded-value cache hands out fresh copies per task; journal
    replay only scans for refs).
    """
    if writable:
        return msgpack.unpackb(
            data, ext_hook=_ext_hook, raw=False, strict_map_key=False
        )
    return msgpack.unpackb(
        data,
        ext_hook=lambda code, payload: _ext_hook(code, payload, writable=False),
        raw=False,
        strict_map_key=False,
    )


def _hash_view(obj: Any) -> Any:
    """Pre-hash transform: DataRef leaves hash by (key, size) only. Locations
    are placement metadata — two refs to the same content must produce the
    same memo key even when the data has moved or been replicated."""
    DataRef = _dataref_type()
    if isinstance(obj, DataRef):
        return msgpack.ExtType(_EXT_DATAREF, packb([obj.key, obj.size]))
    if isinstance(obj, dict):
        return {k: _hash_view(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        out = [_hash_view(v) for v in obj]
        return tuple(out) if isinstance(obj, tuple) else out
    return obj


def payload_hash(obj: Any) -> str:
    """Canonical content hash of a payload (memoization key component)."""
    return hashlib.sha256(packb(_hash_view(obj))).hexdigest()

