"""Fabric-wide telemetry (the follow-up funcX papers' monitoring subsystem).

The paper's headline results (§6) are throughput/latency breakdowns at up to
65k workers and managed elasticity; both need a metrics substrate. This module
provides the three Prometheus-shaped instrument kinds the fabric records:

- :class:`Counter` — monotonically increasing event counts (tasks submitted,
  failovers, warm hits).
- :class:`Gauge` — last-written point-in-time values (queue depth, outstanding
  tasks, desired blocks). A gauge starts *unset* (``value is None``) so
  consumers can distinguish "never measured" from "measured zero" — the
  Forwarder's ``latency_aware`` routing explores unmeasured endpoints first.
- :class:`Histogram` — fixed-bucket distributions (latencies, batch sizes)
  with percentile estimation by linear interpolation inside the bucket.

All instruments live in a :class:`MetricsRegistry`: get-or-create by
``(name, labels)``, with a ``snapshot()`` dict export and a Prometheus-style
``export_text()``. One registry is shared per fabric — ``FunctionService``
creates it, the Forwarder and every registered endpoint/executor/warm-pool
bind to it — so service-tier counters, endpoint-tier gauges, and autoscaler
decisions are one coherent, queryable surface (see docs/scaling.md for the
full catalog of names).

Instruments are cheap: recording is a lock-free attribute bump guarded by a
per-instrument lock only where read-modify-write requires it; registry lookup
is a dict get. The hot path (one histogram observation per task) costs well
under a microsecond.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# Default buckets for latency-flavoured histograms (seconds): 1ms → 60s,
# roughly geometric, matching the dynamic range of Fig. 4/5.
LATENCY_BUCKETS_S: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)

# Default buckets for size-flavoured histograms (batch sizes, counts).
SIZE_BUCKETS: Tuple[float, ...] = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)

# Default buckets for byte-size histograms (journal record frames, payload
# sizes): 64 B → 1 MiB, geometric.
BYTES_BUCKETS: Tuple[float, ...] = (
    64, 256, 1024, 4096, 16384, 65536, 262144, 1048576,
)


def _labels_key(labels: Optional[Dict[str, str]]) -> str:
    if not labels:
        return ""
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return "{" + inner + "}"


class Counter:
    """Monotonic event counter."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Point-in-time value. Starts unset (``value is None``)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value: Optional[float] = None

    def set(self, v: Optional[float]) -> None:
        with self._lock:
            self._value = v if v is None else float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value = (self._value or 0.0) + n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> Optional[float]:
        return self._value


class Histogram:
    """Fixed-bucket histogram: cumulative bucket counts + sum + count.

    ``buckets`` are upper bounds; an implicit +inf bucket catches overflow.
    ``percentile(p)`` estimates by linear interpolation between the bucket's
    lower and upper bound (the +inf bucket reports its lower bound).
    """

    __slots__ = ("name", "buckets", "_lock", "_counts", "_sum", "_count", "_max")

    def __init__(self, name: str, buckets: Sequence[float] = LATENCY_BUCKETS_S):
        self.name = name
        self.buckets: Tuple[float, ...] = tuple(sorted(buckets))
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.buckets) + 1)  # last = +inf
        self._sum = 0.0
        self._count = 0
        self._max = 0.0

    def observe(self, v: float) -> None:
        idx = len(self.buckets)
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                idx = i
                break
        with self._lock:
            self._counts[idx] += 1
            self._sum += v
            self._count += 1
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def mean(self) -> Optional[float]:
        return self._sum / self._count if self._count else None

    def percentile(self, p: float) -> Optional[float]:
        """Estimated p-th percentile (p in [0, 100])."""
        with self._lock:
            total = self._count
            counts = list(self._counts)
        if not total:
            return None
        target = (p / 100.0) * total
        cum = 0
        for i, c in enumerate(counts):
            lo = self.buckets[i - 1] if i > 0 else 0.0
            if i < len(self.buckets):
                hi = self.buckets[i]
            else:  # +inf bucket: best effort, clamp to observed max
                hi = max(self._max, lo)
            if cum + c >= target and c > 0:
                frac = (target - cum) / c
                return lo + frac * (hi - lo)
            cum += c
        return self.buckets[-1]

    def to_dict(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            total, s = self._count, self._sum
        d = {
            "count": total,
            "sum": round(s, 6),
            "mean": round(s / total, 6) if total else None,
            "buckets": {
                ("+inf" if i == len(self.buckets) else repr(self.buckets[i])): c
                for i, c in enumerate(counts)
                if c
            },
        }
        for p in (50, 95, 99):
            q = self.percentile(p)
            d[f"p{p}"] = round(q, 6) if q is not None else None
        return d


class MetricsRegistry:
    """Get-or-create instrument registry with snapshot/export.

    Instruments are keyed by ``name`` plus an optional ``labels`` dict (e.g.
    per-endpoint gauges). Lookup is designed to be called on the hot path —
    components do ``metrics.counter("x").inc()`` per event rather than caching
    instrument handles, so a registry can be rebound wholesale
    (``Endpoint.bind_metrics``) when an endpoint joins a service's fabric.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # -- instrument access -------------------------------------------------
    def counter(self, name: str, labels: Optional[Dict[str, str]] = None) -> Counter:
        key = name + _labels_key(labels)
        c = self._counters.get(key)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(key, Counter(key))
        return c

    def gauge(self, name: str, labels: Optional[Dict[str, str]] = None) -> Gauge:
        key = name + _labels_key(labels)
        g = self._gauges.get(key)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(key, Gauge(key))
        return g

    def histogram(
        self,
        name: str,
        labels: Optional[Dict[str, str]] = None,
        buckets: Sequence[float] = LATENCY_BUCKETS_S,
    ) -> Histogram:
        key = name + _labels_key(labels)
        h = self._histograms.get(key)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(key, Histogram(key, buckets))
        return h

    # -- aggregation over labeled families ---------------------------------
    def family(self, name: str) -> Dict[str, float]:
        """All gauge values whose name matches `name` (any labels), keyed by
        full labeled name. Lets consumers (autoscaler, routing) read every
        per-endpoint series of one metric."""
        prefix = name + "{"
        with self._lock:  # concurrent registration mutates the dict
            gauges = list(self._gauges.items())
        return {
            k: g.value
            for k, g in gauges
            if (k == name or k.startswith(prefix)) and g.value is not None
        }

    # -- export ------------------------------------------------------------
    def snapshot(self) -> dict:
        """Point-in-time export of every instrument, JSON-serializable."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: g.value for k, g in sorted(gauges.items())},
            "histograms": {k: h.to_dict() for k, h in sorted(histograms.items())},
        }

    def export_text(self) -> str:
        """Prometheus-flavoured text exposition (one line per sample)."""
        snap = self.snapshot()
        lines: List[str] = []
        for k, v in snap["counters"].items():
            lines.append(f"{_promname(k, '_total')} {v}")
        for k, v in snap["gauges"].items():
            if v is not None:
                lines.append(f"{_promname(k)} {v}")
        for k, h in snap["histograms"].items():
            lines.append(f"{_promname(k, '_count')} {h['count']}")
            lines.append(f"{_promname(k, '_sum')} {h['sum']}")
        return "\n".join(lines) + "\n"


def _promname(key: str, suffix: str = "") -> str:
    """`endpoint.queue_depth{endpoint=ep}` -> `endpoint_queue_depth{endpoint="ep"}`.
    The `_total`/`_count`/`_sum` suffix goes on the name, before the labels."""
    name, brace, labels = key.partition("{")
    name = name.replace(".", "_") + suffix
    if not brace:
        return name
    parts = []
    for pair in labels.rstrip("}").split(","):
        k, _, v = pair.partition("=")
        v = v.replace("\\", "\\\\").replace('"', '\\"')
        parts.append(f'{k}="{v}"')
    return name + "{" + ",".join(parts) + "}"


def merged_snapshot(registries: Iterable[MetricsRegistry]) -> dict:
    """Union of several registries' snapshots (later registries win on key
    collisions) — used when standalone endpoints keep private registries."""
    out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
    for reg in registries:
        snap = reg.snapshot()
        for section in out:
            out[section].update(snap[section])
    return out
