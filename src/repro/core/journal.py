"""Write-ahead journal: durable workflow/task lifecycle state.

Everything else in the fabric is in-memory — a restart loses every run. The
funcX journal follow-up makes durable task state and exactly-once result
delivery the production story; this module is that tier:

- :class:`Journal` — an append-only write-ahead log of lifecycle records
  (task ``submitted → routed → completed/failed``, workflow-run
  ``started → node_completed → finished``). Records are crc32-framed so a
  crash mid-append leaves a truncated tail that replay detects and skips;
  compaction reuses the atomic tmp-write-then-rename + GC idiom of
  :mod:`repro.checkpoint.checkpointer` (a snapshot segment replaces the
  history it folds).
- :class:`JournalState` — the fold of a journal's records: per-task and
  per-run progress, used by ``FunctionService.resume`` / ``Workflow.resume``
  to re-execute only unfinished work after a fabric restart.
- :class:`ResultStore` — the Forwarder's task-id-keyed idempotent result
  record. A completion lands here exactly once; replayed or speculated
  duplicates are counted in ``journal.duplicate_results`` and dropped.

Exactly-once semantics (see docs/durability.md): a task's *committed result*
— the journal terminal record and the future resolution — happens exactly
once. Execution of work whose completion was never journaled is re-driven on
resume (standard WAL at-least-once execution, exactly-once commitment).
"""
from __future__ import annotations

import os
import struct
import threading
import zlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

from . import serializer
from .metrics import BYTES_BUCKETS, MetricsRegistry

# Frame layout: MAGIC (2B) | payload length (uint32 LE) | crc32 (uint32 LE)
# | msgpack payload. A torn write anywhere in the frame fails either the
# length read or the crc check and terminates replay of that segment.
_MAGIC = b"WJ"
_HEADER = struct.Struct("<II")
_SEG_PREFIX = "seg_"
_SEG_SUFFIX = ".wal"

# Record kinds / task terminal states, shared with the fold below.
KIND_TASK = "task"
KIND_RUN = "run"
TASK_TERMINAL = ("completed", "failed")


def _segment_name(index: int) -> str:
    return f"{_SEG_PREFIX}{index:08d}{_SEG_SUFFIX}"


def _segment_index(name: str) -> Optional[int]:
    if not (name.startswith(_SEG_PREFIX) and name.endswith(_SEG_SUFFIX)):
        return None
    try:
        return int(name[len(_SEG_PREFIX):-len(_SEG_SUFFIX)])
    except ValueError:
        return None


@dataclass
class TaskJournalEntry:
    """Folded journal view of one task's lifecycle."""

    task_id: str
    function_id: Optional[str] = None
    payload: Optional[bytes] = None     # serialized input (None: not resumable)
    container: str = "default"
    requirements: Tuple[str, ...] = ()
    max_retries: int = 2
    owner: Optional[str] = None         # e.g. a workflow run_id; owned tasks
    endpoint_id: Optional[str] = None   # are resumed by their owner, not
    status: str = "submitted"           # submitted | routed | completed | failed
    value: Optional[bytes] = None       # packed result (completed only)
    error: Optional[str] = None

    @property
    def terminal(self) -> bool:
        return self.status in TASK_TERMINAL

    @property
    def resumable(self) -> bool:
        """Re-submittable from the journal alone: incomplete, with a wire
        payload (pass-through payloads never serialize) and no owner."""
        return (
            not self.terminal
            and self.payload is not None
            and self.function_id is not None
            and self.owner is None
        )

    def result(self) -> Any:
        """Unpack the committed result (completed tasks only)."""
        if self.status != "completed" or self.value is None:
            raise ValueError(f"task {self.task_id} has no committed result")
        return serializer.unpackb(self.value)


@dataclass
class RunJournalEntry:
    """Folded journal view of one workflow run."""

    run_id: str
    workflow: str
    document: Optional[bytes] = None    # packed initial document
    nodes: List[str] = field(default_factory=list)
    node_results: Dict[str, Optional[bytes]] = field(default_factory=dict)
    node_skipped: Dict[str, bool] = field(default_factory=dict)
    state: str = "ACTIVE"               # ACTIVE | SUCCEEDED | FAILED | CANCELLED
    resumed: int = 0

    @property
    def terminal(self) -> bool:
        return self.state != "ACTIVE"

    def done_nodes(self) -> List[str]:
        """Nodes with a committed downstream-visible result."""
        return [
            n for n in self.node_results
            if self.node_results[n] is not None or self.node_skipped.get(n)
        ]


class JournalState:
    """The fold of a journal's records. ``duplicate_completions`` counts
    terminal records for already-terminal tasks/nodes — the journal-level
    exactly-once check (a healthy fabric keeps it at zero)."""

    def __init__(self) -> None:
        self.tasks: Dict[str, TaskJournalEntry] = {}
        self.runs: Dict[str, RunJournalEntry] = {}
        self.duplicate_completions = 0
        self.truncated_records = 0

    # -- fold ----------------------------------------------------------------
    def apply(self, rec: dict) -> None:
        kind, event = rec.get("kind"), rec.get("event")
        if kind == KIND_TASK:
            self._apply_task(event, rec)
        elif kind == KIND_RUN:
            self._apply_run(event, rec)

    def _apply_task(self, event: str, rec: dict) -> None:
        tid = rec["task_id"]
        entry = self.tasks.get(tid)
        if entry is None:
            entry = self.tasks[tid] = TaskJournalEntry(task_id=tid)
        if event == "submitted":
            # resubmission after resume re-appends `submitted`: idempotent
            entry.function_id = rec.get("function_id", entry.function_id)
            if rec.get("payload") is not None:
                entry.payload = rec["payload"]
            entry.container = rec.get("container", entry.container)
            entry.requirements = tuple(rec.get("requirements") or ())
            entry.max_retries = rec.get("max_retries", entry.max_retries)
            entry.owner = rec.get("owner", entry.owner)
            if not entry.terminal:
                entry.status = "submitted"
        elif event == "routed":
            entry.endpoint_id = rec.get("endpoint_id")
            if not entry.terminal:
                entry.status = "routed"
        elif event in TASK_TERMINAL:
            if entry.terminal:
                self.duplicate_completions += 1  # first commitment wins
                return
            entry.status = event
            entry.value = rec.get("value")
            entry.error = rec.get("error")

    def _apply_run(self, event: str, rec: dict) -> None:
        rid = rec["run_id"]
        run = self.runs.get(rid)
        if run is None:
            run = self.runs[rid] = RunJournalEntry(
                run_id=rid, workflow=rec.get("workflow", "")
            )
        if event == "started":
            run.workflow = rec.get("workflow", run.workflow)
            run.document = rec.get("document")
            run.nodes = list(rec.get("nodes") or ())
        elif event == "resumed":
            run.resumed += 1
        elif event == "node_completed":
            node = rec["node"]
            if node in run.node_results:
                self.duplicate_completions += 1  # first commitment wins
                return
            run.node_results[node] = rec.get("result")
        elif event == "node_skipped":
            node = rec["node"]
            if node in run.node_results:
                self.duplicate_completions += 1
                return
            run.node_results[node] = None
            run.node_skipped[node] = True
        elif event == "finished":
            if not run.terminal:
                run.state = rec.get("state", "SUCCEEDED")

    # -- queries -------------------------------------------------------------
    def incomplete_tasks(self) -> List[TaskJournalEntry]:
        return [e for e in self.tasks.values() if not e.terminal]

    def incomplete_runs(self) -> List[RunJournalEntry]:
        return [r for r in self.runs.values() if not r.terminal]


class Journal:
    """Append-only write-ahead log over a directory of segment files.

    Every :class:`Journal` instance opens a *fresh* segment — an old
    segment's truncated tail (the record a crash tore mid-write) stays
    quarantined in its file and replay simply stops reading that segment at
    the tear. ``append`` is thread-safe and flushes per record; ``sync=True``
    additionally fsyncs (durable against power loss, ~10x slower).
    """

    def __init__(
        self,
        directory: str,
        sync: bool = False,
        metrics: Optional[MetricsRegistry] = None,
    ):
        self.directory = directory
        self.sync = sync
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._closed = False
        existing = self._segment_indices()
        self._seg_index = (existing[-1] + 1) if existing else 1
        self._fh = open(self._segment_path(self._seg_index), "ab")
        self.metrics.gauge("journal.segments").set(len(self._segment_indices()))

    # -- segment bookkeeping -------------------------------------------------
    def _segment_path(self, index: int) -> str:
        return os.path.join(self.directory, _segment_name(index))

    def _segment_indices(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            idx = _segment_index(name)
            if idx is not None:
                out.append(idx)
        return sorted(out)

    def segments(self) -> List[str]:
        """Segment file paths, oldest first."""
        return [self._segment_path(i) for i in self._segment_indices()]

    # -- append --------------------------------------------------------------
    def append(self, kind: str, event: str, **fields: Any) -> Optional[dict]:
        """Append one record. Returns the record dict, or None when the
        journal is closed — a closed journal drops writes silently, which is
        exactly what a crashed fabric does (the chaos tier's kill-the-fabric
        simulation is ``journal.close()``)."""
        rec = {"kind": kind, "event": event, **fields}
        payload = serializer.packb(rec)
        frame = _MAGIC + _HEADER.pack(len(payload), zlib.crc32(payload)) + payload
        with self._lock:
            if self._closed:
                return None
            self._fh.write(frame)
            self._fh.flush()
            if self.sync:
                os.fsync(self._fh.fileno())
        self.metrics.counter("journal.records_appended").inc()
        self.metrics.counter("journal.bytes_appended").inc(len(frame))
        self.metrics.histogram(
            "journal.record_bytes", buckets=BYTES_BUCKETS
        ).observe(len(frame))
        return rec

    # -- replay --------------------------------------------------------------
    def _read_segment(self, path: str) -> Iterator[dict]:
        try:
            with open(path, "rb") as f:
                data = f.read()
        except FileNotFoundError:
            return
        off, n = 0, len(data)
        while off < n:
            head_end = off + len(_MAGIC) + _HEADER.size
            if data[off:off + len(_MAGIC)] != _MAGIC or head_end > n:
                break  # torn/garbage tail: skip the rest of this segment
            length, crc = _HEADER.unpack(data[off + len(_MAGIC):head_end])
            body_end = head_end + length
            if body_end > n:
                break  # crash mid-payload
            payload = data[head_end:body_end]
            if zlib.crc32(payload) != crc:
                break  # crash mid-frame overwritten / bit rot
            try:
                yield serializer.unpackb(payload)
            except Exception:
                break
            off = body_end
        if off < n:
            self.metrics.counter("journal.truncated_records").inc()

    def records(self) -> Iterator[dict]:
        """Every readable record across all segments, oldest first. A
        truncated tail record (crash during append) is skipped, never
        surfaced."""
        with self._lock:
            if not self._closed:
                self._fh.flush()
        for path in self.segments():
            yield from self._read_segment(path)

    def state(self) -> JournalState:
        st = JournalState()
        for rec in self.records():
            st.apply(rec)
        return st

    # -- compaction (checkpointer idiom: tmp write, rename, GC) --------------
    def compact(self) -> JournalState:
        """Fold the full history into a snapshot segment and GC the segments
        it replaces. The snapshot is written to ``<seg>.tmp`` and renamed
        into place — a crash mid-compaction leaves the old segments intact
        and an orphan ``.tmp`` that is ignored (and removed next compact)."""
        st = self.state()
        with self._lock:
            if self._closed:
                return st
            old = self._segment_indices()
            self._fh.close()
            snap_index = (old[-1] + 1) if old else 1
            snap_path = self._segment_path(snap_index)
            tmp = snap_path + ".tmp"
            with open(tmp, "wb") as f:
                for rec in self._snapshot_records(st):
                    payload = serializer.packb(rec)
                    f.write(
                        _MAGIC
                        + _HEADER.pack(len(payload), zlib.crc32(payload))
                        + payload
                    )
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, snap_path)
            for idx in old:  # GC the history the snapshot folded
                try:
                    os.remove(self._segment_path(idx))
                except FileNotFoundError:
                    pass
            for name in os.listdir(self.directory):  # orphan tmps from crashes
                if name.endswith(".tmp"):
                    try:
                        os.remove(os.path.join(self.directory, name))
                    except FileNotFoundError:
                        pass
            self._seg_index = snap_index + 1
            self._fh = open(self._segment_path(self._seg_index), "ab")
        self.metrics.counter("journal.compactions").inc()
        self.metrics.gauge("journal.segments").set(len(self._segment_indices()))
        return st

    @staticmethod
    def _snapshot_records(st: JournalState) -> Iterator[dict]:
        """Minimal record stream reproducing `st` when folded."""
        for e in st.tasks.values():
            yield {
                "kind": KIND_TASK, "event": "submitted", "task_id": e.task_id,
                "function_id": e.function_id, "payload": e.payload,
                "container": e.container, "requirements": list(e.requirements),
                "max_retries": e.max_retries, "owner": e.owner,
            }
            if e.endpoint_id is not None:
                yield {
                    "kind": KIND_TASK, "event": "routed",
                    "task_id": e.task_id, "endpoint_id": e.endpoint_id,
                }
            if e.terminal:
                yield {
                    "kind": KIND_TASK, "event": e.status, "task_id": e.task_id,
                    "value": e.value, "error": e.error,
                }
        for r in st.runs.values():
            yield {
                "kind": KIND_RUN, "event": "started", "run_id": r.run_id,
                "workflow": r.workflow, "document": r.document,
                "nodes": list(r.nodes),
            }
            for node, result in r.node_results.items():
                if r.node_skipped.get(node):
                    yield {
                        "kind": KIND_RUN, "event": "node_skipped",
                        "run_id": r.run_id, "node": node,
                    }
                else:
                    yield {
                        "kind": KIND_RUN, "event": "node_completed",
                        "run_id": r.run_id, "node": node, "result": result,
                    }
            if r.terminal:
                yield {
                    "kind": KIND_RUN, "event": "finished",
                    "run_id": r.run_id, "state": r.state,
                }

    # -- lifecycle -----------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Stop accepting writes (subsequent appends drop silently — the
        crashed-fabric simulation) and release the file handle."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            try:
                self._fh.flush()
            finally:
                self._fh.close()


class ResultStore:
    """Task-id-keyed idempotent result record (the Forwarder's exactly-once
    authority). ``record`` accepts the first terminal outcome for a task and
    rejects every later one, counting it in ``journal.duplicate_results``;
    ``prime`` seeds completed ids from a journal replay without counting.
    Bounded FIFO so a long-lived fabric cannot grow it without limit."""

    def __init__(
        self,
        max_entries: int = 65536,
        metrics: Optional[MetricsRegistry] = None,
    ):
        self.max_entries = max_entries
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._lock = threading.Lock()
        self._entries: "OrderedDict[str, Tuple[Any, Optional[BaseException]]]" = (
            OrderedDict()
        )

    def record(
        self,
        task_id: str,
        value: Any = None,
        error: Optional[BaseException] = None,
    ) -> bool:
        """Record a terminal outcome. Returns False (and bumps the duplicate
        counter) when `task_id` already has one — the caller must not apply
        the outcome again."""
        with self._lock:
            if task_id in self._entries:
                dup = True
            else:
                dup = False
                self._entries[task_id] = (value, error)
                while len(self._entries) > self.max_entries:
                    self._entries.popitem(last=False)
        if dup:
            self.metrics.counter("journal.duplicate_results").inc()
        return not dup

    def prime(self, task_id: str) -> None:
        """Seed a completed task id (journal replay at resume) so replayed
        late deliveries dedupe — never counted as a duplicate itself."""
        with self._lock:
            if task_id not in self._entries:
                self._entries[task_id] = (None, None)
                while len(self._entries) > self.max_entries:
                    self._entries.popitem(last=False)

    def get(self, task_id: str) -> Optional[Tuple[Any, Optional[BaseException]]]:
        with self._lock:
            return self._entries.get(task_id)

    def __contains__(self, task_id: str) -> bool:
        with self._lock:
            return task_id in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


@dataclass
class ResumeReport:
    """What :meth:`FunctionService.resume` rehydrated from the journal.

    ``futures`` — fresh TaskFutures for re-submitted standalone tasks, keyed
    by their original task_id (ids are stable across restarts so terminal
    journal records keep matching). ``runs`` — resumed WorkflowRuns by
    run_id. ``skipped`` — (id, reason) pairs for work the journal knows about
    but this fabric cannot resume (unregistered function, no workflow
    definition supplied, unserializable payload)."""

    futures: Dict[str, Any] = field(default_factory=dict)
    runs: Dict[str, Any] = field(default_factory=dict)
    skipped: List[Tuple[str, str]] = field(default_factory=list)
    state: Optional[JournalState] = None
