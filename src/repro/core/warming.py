"""Executable warming (the paper's "container warming", §5.5, Table 4).

On a TPU pod the cold-start cost that container warming amortizes is not
process boot but **trace + lower + XLA compile + weight residency**. The warm
pool caches compiled executables keyed by (function_id, container variant,
abstract input signature); a hit is a "warm container", a miss pays the
compile ("cold container instantiation", Table 4). Entries expire after a TTL
exactly like funcX's 5–10 minute container keep-alive, and an LRU bound caps
device/host memory spent on retained executables.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass

from typing import Any, Callable, Optional, Tuple

from .metrics import MetricsRegistry


@dataclass
class WarmEntry:
    executable: Any
    compile_time_s: float
    created: float
    last_used: float
    uses: int = 0


class WarmPool:
    """TTL + LRU cache of compiled executables."""

    def __init__(
        self,
        ttl_s: float = 300.0,
        max_entries: int = 256,
        metrics: Optional[MetricsRegistry] = None,
    ):
        self.ttl_s = ttl_s
        self.max_entries = max_entries
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._lock = threading.Lock()
        self._entries: OrderedDict[Tuple, WarmEntry] = OrderedDict()
        self.cold_starts = 0
        self.warm_hits = 0
        self.evictions = 0

    def get_or_compile(
        self,
        key: Tuple,
        compile_fn: Callable[[], Any],
        now: Optional[float] = None,
    ) -> Tuple[Any, bool, float]:
        """Returns (executable, was_cold, compile_time_s).

        The compile runs outside the lock: concurrent cold-starts of the same
        key may duplicate work (funcX likewise boots one container per
        concurrent cold request) but the winner-stays write is idempotent.
        """
        now = time.monotonic() if now is None else now
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None and (now - entry.last_used) <= self.ttl_s:
                entry.last_used = now
                entry.uses += 1
                self._entries.move_to_end(key)
                self.warm_hits += 1
                self.metrics.counter("warming.warm_hits").inc()
                return entry.executable, False, 0.0
            if entry is not None:  # expired
                del self._entries[key]
                self.evictions += 1
                self.metrics.counter("warming.evictions").inc()

        t0 = time.monotonic()
        executable = compile_fn()
        dt = time.monotonic() - t0

        with self._lock:
            self.cold_starts += 1
            self._entries[key] = WarmEntry(
                executable=executable, compile_time_s=dt, created=now, last_used=now, uses=1
            )
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.evictions += 1
                self.metrics.counter("warming.evictions").inc()
        self.metrics.counter("warming.cold_starts").inc()
        self.metrics.histogram("warming.compile_time_s").observe(dt)
        return executable, True, dt

    def warm(self, key: Tuple, compile_fn: Callable[[], Any]) -> float:
        """Pre-warm (paper: functions may be warmed ahead of invocation)."""
        _, was_cold, dt = self.get_or_compile(key, compile_fn)
        return dt if was_cold else 0.0

    def sweep(self, now: Optional[float] = None) -> int:
        """Evict expired entries. Called opportunistically by executor loops."""
        now = time.monotonic() if now is None else now
        with self._lock:
            expired = [k for k, e in self._entries.items() if (now - e.last_used) > self.ttl_s]
            for k in expired:
                del self._entries[k]
            self.evictions += len(expired)
        if expired:
            self.metrics.counter("warming.evictions").inc(len(expired))
        return len(expired)

    def contains(self, key: Tuple) -> bool:
        with self._lock:
            e = self._entries.get(key)
            return e is not None and (time.monotonic() - e.last_used) <= self.ttl_s

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._entries),
                "cold_starts": self.cold_starts,
                "warm_hits": self.warm_hits,
                "evictions": self.evictions,
                "total_compile_s": sum(e.compile_time_s for e in self._entries.values()),
            }
