"""Worker: executes one function at a time (paper §5.3).

funcX workers "persist within containers and each executes one function at a
time ... once a function is received it is deserialized and executed, and the
serialized results are returned via the executor." Here a worker is a thread
(on TPU: pinned to a device slice); the container is the warm executable it
runs inside (see `warming.py`).
"""
from __future__ import annotations

import queue
import threading
import time
import traceback
from dataclasses import dataclass
from typing import Any, Callable, Optional

from . import serializer
from .futures import TaskEnvelope
from .registry import FunctionRegistry, RegisteredFunction
from .warming import WarmPool


@dataclass
class TaskResult:
    envelope: TaskEnvelope
    value: Any = None                 # deserialized result (or bytes if wire=True)
    error: Optional[str] = None
    exception: Optional[BaseException] = None
    worker_id: str = ""
    cold_start: bool = False
    compile_time_s: float = 0.0
    batch_id: Optional[str] = None    # TaskBatch frame this task arrived in


class _JaxExecutable:
    """jit-wrapped registered function; AOT-compiles on construction when a
    sample payload is available (so WarmPool timing captures the real compile
    cost, the Table-4 'container instantiation' analogue)."""

    def __init__(self, rf: RegisteredFunction, sample_payload: Any = None):
        import jax

        jit_kwargs = rf.metadata.get("jit_kwargs", {})
        self._jitted = jax.jit(rf.fn, **jit_kwargs)
        if sample_payload is not None:
            try:
                self._jitted.lower(sample_payload).compile()
            except Exception:
                pass  # shape-polymorphic usage: compile lazily per call

    def __call__(self, payload: Any) -> Any:
        out = self._jitted(payload)
        import jax

        return jax.block_until_ready(out)


def build_executable(rf: RegisteredFunction, sample_payload: Any = None) -> Callable:
    if rf.metadata.get("jax_jit", False):
        return _JaxExecutable(rf, sample_payload)
    return rf.fn


class Worker(threading.Thread):
    def __init__(
        self,
        worker_id: str,
        inbox: "queue.Queue[TaskEnvelope]",
        outbox: "queue.Queue[TaskResult]",
        registry: FunctionRegistry,
        warm_pool: WarmPool,
        poll_s: float = 0.01,
    ):
        super().__init__(name=worker_id, daemon=True)
        self.worker_id = worker_id
        self.inbox = inbox
        self.outbox = outbox
        self.registry = registry
        self.warm_pool = warm_pool
        self.poll_s = poll_s
        self._stop_event = threading.Event()
        self._drop_inflight = threading.Event()  # simulated node failure
        self.busy = False
        self.executed = 0

    # -- failure injection (tests / Fig. 7 benchmark) --------------------
    def simulate_failure(self) -> None:
        """Drop whatever is executing, produce no results, stop the loop."""
        self._drop_inflight.set()
        self._stop_event.set()

    def stop(self) -> None:
        self._stop_event.set()

    # -- main loop --------------------------------------------------------
    def run(self) -> None:
        while not self._stop_event.is_set():
            try:
                env = self.inbox.get(timeout=self.poll_s)
            except queue.Empty:
                continue
            self.busy = True
            try:
                result = self._execute(env)
            finally:
                self.busy = False
            if self._drop_inflight.is_set():
                return  # vanish without reporting — watchdog must recover
            self.outbox.put(result)
            self.executed += 1

    def _execute(self, env: TaskEnvelope) -> TaskResult:
        env.timestamps.exec_start = time.monotonic()
        try:
            rf = self.registry.get(env.function_id)
            payload = serializer.unpackb(env.payload) if isinstance(env.payload, bytes) else env.payload
            key = (env.function_id, env.container)
            executable, cold, dt = self.warm_pool.get_or_compile(
                key, lambda: build_executable(rf, payload)
            )
            value = executable(payload)
            if rf.metadata.get("serialize_result", True):
                # wire-faithful: results cross the executor/manager boundary as
                # bytes; deserialized once at the service edge.
                value = serializer.unpackb(serializer.packb(value))
            env.timestamps.exec_end = time.monotonic()
            return TaskResult(
                envelope=env, value=value, worker_id=self.worker_id,
                cold_start=cold, compile_time_s=dt, batch_id=env.batch_id,
            )
        except BaseException as exc:  # noqa: BLE001 — report, don't die
            env.timestamps.exec_end = time.monotonic()
            return TaskResult(
                envelope=env,
                error=f"{type(exc).__name__}: {exc}\n{traceback.format_exc(limit=5)}",
                exception=exc,
                worker_id=self.worker_id,
                batch_id=env.batch_id,
            )
