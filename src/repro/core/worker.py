"""Worker: executes one function at a time (paper §5.3).

funcX workers "persist within containers and each executes one function at a
time ... once a function is received it is deserialized and executed, and the
serialized results are returned via the executor." Here a worker is a thread
(on TPU: pinned to a device slice); it persists within one
:class:`~repro.core.containers.ContainerPool` and the container is the warm
executable it runs inside (see `warming.py`).

Idle workers block on the pool inbox — no timeout-poll — so hundreds of idle
workers across container pools burn no CPU. Retirement is a stop-sentinel
(:data:`Worker.STOP`) delivered through the same inbox: tasks queued ahead of
the sentinel still execute, then the worker exits.
"""
from __future__ import annotations

import queue
import threading
import time
import traceback
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Callable, Optional

from . import serializer

if TYPE_CHECKING:  # imported lazily to avoid a registry<->containers cycle
    from .registry import RegisteredFunction
    from .warming import WarmPool


@dataclass
class TaskResult:
    envelope: Any                     # TaskEnvelope
    value: Any = None                 # deserialized result (or bytes if wire=True)
    error: Optional[str] = None
    exception: Optional[BaseException] = None
    worker_id: str = ""
    cold_start: bool = False
    compile_time_s: float = 0.0
    batch_id: Optional[str] = None    # TaskBatch frame this task arrived in


class SiteRuntime:
    """Endpoint-scoped runtime state handed to *site-aware* functions.

    A function registered with ``site_aware=True`` metadata receives
    ``(payload, site)`` instead of ``(payload,)``: the dispatching endpoint
    attaches its SiteRuntime to every envelope, so the function can reach
    state that must live *where the task runs* — the serving tier's per-
    endpoint model hosts (KV-cache slots) are the canonical tenant. State is
    a keyed get-or-create map so concurrent workers build each service once.
    """

    def __init__(self, endpoint_id: str, name: str,
                 metrics_fn: Optional[Callable[[], Any]] = None):
        self.endpoint_id = endpoint_id
        self.name = name
        self._metrics_fn = metrics_fn
        self._state: dict = {}
        self._lock = threading.Lock()

    @property
    def metrics(self):
        """The owning endpoint's *current* MetricsRegistry (endpoints rebind
        to the service registry at registration, so this is read late)."""
        return self._metrics_fn() if self._metrics_fn is not None else None

    def get_or_create(self, key: Any, factory: Callable[[], Any]) -> Any:
        with self._lock:
            if key not in self._state:
                self._state[key] = factory()
            return self._state[key]

    def pop(self, key: Any) -> Any:
        with self._lock:
            return self._state.pop(key, None)


_default_site: Optional[SiteRuntime] = None
_default_site_lock = threading.Lock()


def default_site() -> SiteRuntime:
    """Fallback SiteRuntime for tasks that bypassed endpoint dispatch
    (direct executor submission in tests, in-process engine use)."""
    global _default_site
    with _default_site_lock:
        if _default_site is None:
            from .metrics import MetricsRegistry

            registry = MetricsRegistry()
            _default_site = SiteRuntime(
                "local", "local", metrics_fn=lambda: registry
            )
        return _default_site


def strip_traceback(exc: BaseException) -> BaseException:
    """Drop the traceback (frames + their locals) from `exc` and its
    cause/context chain. A TaskResult's exception outlives the task for as
    long as the caller holds the future; carrying live frames across the
    executor boundary would pin every local of the failed call for that
    lifetime. The formatted traceback string in TaskResult.error survives.
    """
    seen = set()
    stack = [exc]
    while stack:
        e = stack.pop()
        if e is None or id(e) in seen:
            continue
        seen.add(id(e))
        e.__traceback__ = None
        stack.extend((e.__cause__, e.__context__))
    return exc


class _JaxExecutable:
    """jit-wrapped registered function; AOT-compiles on construction when a
    sample payload is available (so WarmPool timing captures the real compile
    cost, the Table-4 'container instantiation' analogue)."""

    def __init__(self, rf: "RegisteredFunction", sample_payload: Any = None):
        import jax

        jit_kwargs = rf.metadata.get("jit_kwargs", {})
        self._jitted = jax.jit(rf.fn, **jit_kwargs)
        if sample_payload is not None:
            try:
                self._jitted.lower(sample_payload).compile()
            except Exception:
                pass  # shape-polymorphic usage: compile lazily per call

    def __call__(self, payload: Any) -> Any:
        out = self._jitted(payload)
        import jax

        return jax.block_until_ready(out)


def build_executable(rf: "RegisteredFunction", sample_payload: Any = None) -> Callable:
    # Simulated container instantiation cost (paper Table 4: funcX containers
    # take seconds to boot). Benchmarks use this to make cold starts
    # deterministic — XLA's in-process executable cache makes *re*-compiles of
    # identical HLO nearly free, which would otherwise hide the cost a second
    # endpoint pays to warm up.
    boot_s = rf.metadata.get("container_boot_s", 0.0)
    if boot_s:
        time.sleep(boot_s)
    if rf.metadata.get("jax_jit", False):
        return _JaxExecutable(rf, sample_payload)
    return rf.fn


class Worker(threading.Thread):
    #: stop sentinel: delivered through the inbox so a blocked worker wakes
    #: exactly once to retire (one sentinel stops one worker)
    STOP = object()

    def __init__(
        self,
        worker_id: str,
        inbox: "queue.Queue",
        outbox: "queue.Queue[TaskResult]",
        registry,
        warm_pool: "WarmPool",
        on_stop: Optional[Callable[[], None]] = None,
    ):
        super().__init__(name=worker_id, daemon=True)
        self.worker_id = worker_id
        self.inbox = inbox
        self.outbox = outbox
        self.registry = registry
        self.warm_pool = warm_pool
        # invoked when a STOP sentinel is consumed (pool bookkeeping: the
        # sentinel is no longer pending in the shared inbox)
        self._on_stop = on_stop
        self._drop_inflight = threading.Event()  # simulated node failure
        self.busy = False
        self.executed = 0

    # -- failure injection (tests / Fig. 7 benchmark) --------------------
    def simulate_failure(self) -> None:
        """Drop whatever is executing, produce no results, stop the loop."""
        self._drop_inflight.set()

    def stop(self) -> None:
        """Graceful retirement: tasks already queued ahead of the sentinel
        still execute; the worker consuming the sentinel exits."""
        self.inbox.put(Worker.STOP)

    # -- main loop --------------------------------------------------------
    def run(self) -> None:
        while True:
            item = self.inbox.get()  # blocking: idle workers burn no CPU
            if item is Worker.STOP:
                if self._on_stop is not None:
                    self._on_stop()
                return
            if self._drop_inflight.is_set():
                return  # vanish without reporting — watchdog must recover
            self.busy = True
            try:
                result = self._execute(item)
            finally:
                self.busy = False
            if self._drop_inflight.is_set():
                return  # killed mid-task: the result vanishes with the node
            self.outbox.put(result)
            self.executed += 1

    def _execute(self, env) -> TaskResult:
        env.timestamps.exec_start = time.monotonic()
        try:
            rf = self.registry.get(env.function_id)
            payload = serializer.unpackb(env.payload) if isinstance(env.payload, bytes) else env.payload
            if getattr(env, "data_refs", ()):
                # materialize DataRef leaves in parallel across workers; the
                # dispatching endpoint warmed its locality cache and attached
                # it as env.data_cache. A path that bypassed dispatch (direct
                # executor submission, speculation backups holding unpacked
                # payloads) resolves straight from the refs' store locations.
                from .datastore import resolve_payload

                payload = resolve_payload(
                    payload,
                    cache=getattr(env, "data_cache", None),
                    decoded=getattr(env, "data_decoded", None),
                )
            key = (env.function_id, env.container)
            executable, cold, dt = self.warm_pool.get_or_compile(
                key, lambda: build_executable(rf, payload)
            )
            if rf.metadata.get("site_aware", False):
                # endpoint-scoped functions see where they run: the serving
                # tier resolves its per-endpoint model host through this
                site = getattr(env, "site", None)
                value = executable(payload, site or default_site())
            else:
                value = executable(payload)
            if getattr(env, "spill_store", None) and env.spill_threshold:
                # result spill: oversized result leaves stay in the object
                # store near where they were computed; only refs travel the
                # result path back through the fabric
                from .datastore import get_store, spill_payload

                store = get_store(env.spill_store)
                value, _ = spill_payload(value, store, env.spill_threshold)
            if rf.metadata.get("serialize_result", True):
                # wire-faithful: results cross the executor/manager boundary as
                # bytes; deserialized once at the service edge.
                value = serializer.unpackb(serializer.packb(value))
            env.timestamps.exec_end = time.monotonic()
            return TaskResult(
                envelope=env, value=value, worker_id=self.worker_id,
                cold_start=cold, compile_time_s=dt, batch_id=env.batch_id,
            )
        except BaseException as exc:  # noqa: BLE001 — report, don't die
            env.timestamps.exec_end = time.monotonic()
            error = f"{type(exc).__name__}: {exc}\n{traceback.format_exc(limit=5)}"
            return TaskResult(
                envelope=env,
                error=error,
                # the exception crosses the executor boundary without its
                # traceback: live frames (and their locals) must not stay
                # pinned for the lifetime of the result/memo cache
                exception=strip_traceback(exc),
                worker_id=self.worker_id,
                batch_id=env.batch_id,
            )
