"""Executor: per-node container pools (paper §5.3–5.4).

"Executors represent, and communicate on behalf of, the collective capacity
of the workers on a single node" — they partition the node among *typed
container pools* (one per :class:`~repro.core.containers.ContainerSpec` the
node hosts), advertise available capacity per container type to the manager,
emit heartbeats, and forward results. Prefetch (§5.5) is the capacity each
pool advertises beyond currently-idle workers.

Heterogeneity: every pool carries a capability set; the scheduler only hands
an executor tasks some pool can run (``can_run``), and capacity is advertised
per container (``free_capacity(container)``) instead of one scalar. Pools
resize on demand — workers spin up when matching tasks arrive and shrink back
to ``min_workers`` after a keep-alive idle period, unified with the WarmPool
TTL that retires the compiled executables those workers would have reused.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .containers import (
    CapabilityError,
    ContainerPool,
    ContainerSpec,
    default_container_spec,
)
from .futures import TaskEnvelope
from .heartbeat import HeartbeatMonitor
from .interchange import ResultBatch
from .metrics import MetricsRegistry
from .registry import FunctionRegistry
from .warming import WarmPool
from .worker import TaskResult


class Executor:
    def __init__(
        self,
        executor_id: str,
        registry: FunctionRegistry,
        result_queue: "queue.Queue[ResultBatch]",
        containers: Optional[Sequence[ContainerSpec]] = None,
        prefetch: int = 0,
        warm_ttl_s: float = 300.0,
        container_keep_alive_s: Optional[float] = None,
        monitor: Optional[HeartbeatMonitor] = None,
        heartbeat_interval_s: float = 2.0,
        result_max_batch: int = 64,
        metrics: Optional[MetricsRegistry] = None,
    ):
        self.executor_id = executor_id
        self.registry = registry
        self.result_queue = result_queue
        self.prefetch = prefetch
        self.result_max_batch = result_max_batch
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.warm_pool = WarmPool(ttl_s=warm_ttl_s, metrics=self.metrics)
        # container keep-alive defaults to the warm TTL: workers and the
        # compiled executables they reuse retire on the same clock
        self.container_keep_alive_s = (
            warm_ttl_s if container_keep_alive_s is None else container_keep_alive_s
        )
        self.monitor = monitor
        self.heartbeat_interval_s = heartbeat_interval_s

        self._alive = True
        self._suspended = False
        self._lock = threading.Lock()
        self.in_flight: Dict[str, TaskEnvelope] = {}
        self.completed = 0

        specs = list(containers) if containers else [default_container_spec(4)]
        if len({s.name for s in specs}) != len(specs):
            raise ValueError(f"duplicate container names in {[s.name for s in specs]}")
        self.specs: Dict[str, ContainerSpec] = {s.name: s for s in specs}
        outbox: "queue.Queue[TaskResult]" = queue.Queue()
        self._outbox = outbox
        self.pools: Dict[str, ContainerPool] = {
            s.name: ContainerPool(
                spec=s,
                executor_id=executor_id,
                outbox=outbox,
                registry=registry,
                warm_pool=self.warm_pool,
            )
            for s in specs
        }

        self._forwarder = threading.Thread(
            target=self._forward_results, name=f"{executor_id}/fwd", daemon=True
        )
        self._forwarder.start()

        if monitor is not None:
            monitor.register(executor_id)
            self._beater = threading.Thread(
                target=self._beat_loop, name=f"{executor_id}/hb", daemon=True
            )
            self._beater.start()

    # -- capability surface (consumed by the resource-aware scheduler) ----
    def capabilities(self) -> frozenset:
        """Union of every hosted container's capability set."""
        return frozenset().union(*(s.capabilities for s in self.specs.values()))

    def pool_for(self, env: TaskEnvelope) -> Optional[ContainerPool]:
        """The pool `env` runs in: the container it names when that pool
        satisfies its requirements, else the first pool that does. The seed's
        container-as-cache-key usage (arbitrary names, no requirements) keeps
        working: an unknown name with empty requirements lands in the first
        (default) pool, warm-keyed by the requested name."""
        required = env.requirements
        pool = self.pools.get(env.container)
        if pool is not None and pool.spec.provides(required):
            return pool
        for pool in self.pools.values():
            if pool.spec.provides(required):
                return pool
        return None

    def can_run(self, env: TaskEnvelope) -> bool:
        return self.pool_for(env) is not None

    # -- capacity advertising (enables executor-side batching) -----------
    def idle_workers(self) -> int:
        return sum(p.idle_workers() for p in self.pools.values())

    def worker_count(self) -> int:
        return sum(p.live_workers() for p in self.pools.values())

    @property
    def max_workers(self) -> int:
        """Advertised ceiling: what this node can grow to across pools."""
        return sum(s.max_workers for s in self.specs.values())

    def free_capacity(self, container: str) -> int:
        """Per-container-type capacity advertisement (idle + demand headroom
        + prefetch − backlog) for the named pool."""
        if not self.accepting():
            return 0
        pool = self.pools.get(container)
        return pool.free_capacity(self.prefetch) if pool is not None else 0

    def free_capacity_for(self, env: TaskEnvelope) -> int:
        """Capacity advertisement for the pool `env` would run in."""
        if not self.accepting():
            return 0
        pool = self.pool_for(env)
        return pool.free_capacity(self.prefetch) if pool is not None else 0

    def queued_tasks(self) -> int:
        """Backlog across every pool inbox (autoscaler drain check)."""
        return sum(p.queued() for p in self.pools.values())

    def accepting(self) -> bool:
        return self._alive and not self._suspended

    def has_warm(self, key: Tuple) -> bool:
        return self.warm_pool.contains(key)

    # -- task intake ------------------------------------------------------
    def submit(self, env: TaskEnvelope) -> None:
        self.submit_batch([env])

    def submit_batch(self, envs: List[TaskEnvelope]) -> None:
        """Accept a manager-pulled batch: one in-flight bookkeeping pass for
        the whole batch, then one pool submission per container type (the
        pool grows itself to meet the backlog)."""
        with self._lock:
            for env in envs:
                env.executor_id = self.executor_id
                self.in_flight[env.task_id] = env
        by_pool: Dict[str, List[TaskEnvelope]] = {}
        unroutable: List[TaskEnvelope] = []
        for env in envs:
            pool = self.pool_for(env)
            if pool is None:
                unroutable.append(env)
            else:
                by_pool.setdefault(pool.spec.name, []).append(env)
        for name, batch in by_pool.items():
            self.pools[name].submit(batch)
        for env in unroutable:
            # defensive: the scheduler filters on can_run(), so this only
            # fires when specs changed between choice and delivery — report
            # a capability error instead of stranding the task
            self.metrics.counter("container.capability_misses").inc()
            exc = CapabilityError(
                f"executor {self.executor_id} has no container providing "
                f"{sorted(env.requirements)} (hosts {sorted(self.specs)})"
            )
            self._outbox.put(TaskResult(envelope=env, error=str(exc), exception=exc))

    def take_in_flight(self) -> List[TaskEnvelope]:
        """Called by the watchdog after this executor is declared dead."""
        with self._lock:
            tasks = list(self.in_flight.values())
            self.in_flight.clear()
            return tasks

    def drain_queued(self) -> List[TaskEnvelope]:
        """Recover tasks still sitting in pool inboxes (watchdog path)."""
        drained: List[TaskEnvelope] = []
        for pool in self.pools.values():
            drained.extend(pool.drain_queued())
        return drained

    def running_longer_than(self, seconds: float) -> List[TaskEnvelope]:
        """Straggler candidates: dispatched here and executing for > seconds."""
        now = time.monotonic()
        with self._lock:
            return [
                e
                for e in self.in_flight.values()
                if e.timestamps.exec_start and (now - e.timestamps.exec_start) > seconds
            ]

    # -- internals ----------------------------------------------------------
    def _forward_results(self) -> None:
        """Drain the workers' outbox into ResultBatch frames: block for the
        first result (latency), then sweep whatever else is ready (throughput)
        so the manager pays one queue round-trip per frame, not per result."""
        while self._alive:
            try:
                res = self._outbox.get(timeout=0.02)
            except queue.Empty:
                continue
            results = [res]
            while len(results) < self.result_max_batch:
                try:
                    results.append(self._outbox.get_nowait())
                except queue.Empty:
                    break
            with self._lock:
                for r in results:
                    self.in_flight.pop(r.envelope.task_id, None)
                self.completed += len(results)
            self.metrics.counter("executor.tasks_executed").inc(len(results))
            service_time = self.metrics.histogram("executor.service_time_s")
            for r in results:
                ts = r.envelope.timestamps
                if ts.exec_end and ts.exec_start:
                    service_time.observe(ts.exec_end - ts.exec_start)
            self.result_queue.put(ResultBatch(results=results))

    def _beat_loop(self) -> None:
        while self._alive:
            self.monitor.beat(self.executor_id)
            self.warm_pool.sweep()
            self.maintain()
            time.sleep(self.heartbeat_interval_s)

    def maintain(self, now: Optional[float] = None) -> None:
        """Heartbeat-cadence pool upkeep: shrink idle pools back to their
        floors and publish per-container telemetry."""
        for name, pool in self.pools.items():
            retired = pool.shrink_idle(self.container_keep_alive_s, now=now)
            labels = {"container": name, "executor": self.executor_id}
            if retired:
                self.metrics.counter("container.pool_shrinks").inc(retired)
            self.metrics.gauge("container.pool_size", labels).set(pool.live_workers())
            self.metrics.gauge("container.queue_depth", labels).set(pool.queued())

    # -- lifecycle ------------------------------------------------------------
    def kill(self) -> None:
        """Simulated node failure: heartbeats stop, in-flight results vanish."""
        self._alive = False
        for pool in self.pools.values():
            pool.kill()

    def suspend(self) -> None:
        """Paper: 'suspend executors to prevent further tasks being scheduled
        to failed executors'. Also the first step of an autoscaler drain."""
        self._suspended = True

    def resume(self) -> None:
        """Undo a suspend — the autoscaler resumes an executor when work
        raced its drain attempt (a suspended-but-live executor is healthy)."""
        self._suspended = False

    def shutdown(self) -> None:
        self._alive = False
        for pool in self.pools.values():
            # A worker mid-execution is left to finish and exit on its own
            # (daemon thread): joining it would stall the caller — e.g. the
            # endpoint manager loop releasing a dead block — long enough for
            # the fabric watchdog to declare the whole endpoint dead.
            pool.stop(join=True)
        if self.monitor is not None:
            self.monitor.deregister(self.executor_id)

    def stats(self) -> dict:
        return {
            "executor_id": self.executor_id,
            "workers": self.worker_count(),
            "max_workers": self.max_workers,
            "capabilities": sorted(self.capabilities()),
            "idle": self.idle_workers(),
            "queued": self.queued_tasks(),
            "in_flight": len(self.in_flight),
            "completed": self.completed,
            "warm": self.warm_pool.stats(),
            "containers": {name: p.stats() for name, p in self.pools.items()},
            "accepting": self.accepting(),
        }
