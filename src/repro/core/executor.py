"""Executor: per-node worker pool (paper §5.3).

"Executors represent, and communicate on behalf of, the collective capacity
of the workers on a single node" — they partition the node among workers,
advertise available capacity to the manager (which enables executor-side
batching), emit heartbeats, and forward results. Prefetch (§5.5) is the
capacity they advertise beyond currently-idle workers.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, List, Optional, Tuple

from .futures import TaskEnvelope
from .heartbeat import HeartbeatMonitor
from .interchange import ResultBatch
from .metrics import MetricsRegistry
from .registry import FunctionRegistry
from .warming import WarmPool
from .worker import TaskResult, Worker


class Executor:
    def __init__(
        self,
        executor_id: str,
        registry: FunctionRegistry,
        result_queue: "queue.Queue[ResultBatch]",
        n_workers: int = 4,
        prefetch: int = 0,
        warm_ttl_s: float = 300.0,
        monitor: Optional[HeartbeatMonitor] = None,
        heartbeat_interval_s: float = 2.0,
        result_max_batch: int = 64,
        metrics: Optional[MetricsRegistry] = None,
    ):
        self.executor_id = executor_id
        self.registry = registry
        self.result_queue = result_queue
        self.n_workers = n_workers
        self.prefetch = prefetch
        self.result_max_batch = result_max_batch
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.warm_pool = WarmPool(ttl_s=warm_ttl_s, metrics=self.metrics)
        self.inbox: "queue.Queue[TaskEnvelope]" = queue.Queue()
        self.monitor = monitor
        self.heartbeat_interval_s = heartbeat_interval_s

        self._alive = True
        self._suspended = False
        self._lock = threading.Lock()
        self.in_flight: Dict[str, TaskEnvelope] = {}
        self.completed = 0

        self.workers: List[Worker] = []
        outbox: "queue.Queue[TaskResult]" = queue.Queue()
        self._outbox = outbox
        for i in range(n_workers):
            w = Worker(
                worker_id=f"{executor_id}/w{i}",
                inbox=self.inbox,
                outbox=outbox,
                registry=registry,
                warm_pool=self.warm_pool,
            )
            self.workers.append(w)
            w.start()

        self._forwarder = threading.Thread(
            target=self._forward_results, name=f"{executor_id}/fwd", daemon=True
        )
        self._forwarder.start()

        if monitor is not None:
            monitor.register(executor_id)
            self._beater = threading.Thread(
                target=self._beat_loop, name=f"{executor_id}/hb", daemon=True
            )
            self._beater.start()

    # -- capacity advertising (enables executor-side batching) -----------
    def idle_workers(self) -> int:
        return sum(1 for w in self.workers if not w.busy and w.is_alive())

    def free_capacity(self) -> int:
        """Tasks this executor is willing to accept right now: idle workers
        plus the prefetch allowance, minus what is already queued locally."""
        if not self.accepting():
            return 0
        return max(0, self.idle_workers() + self.prefetch - self.inbox.qsize())

    def accepting(self) -> bool:
        return self._alive and not self._suspended

    def has_warm(self, key: Tuple) -> bool:
        return self.warm_pool.contains(key)

    # -- task intake ------------------------------------------------------
    def submit(self, env: TaskEnvelope) -> None:
        self.submit_batch([env])

    def submit_batch(self, envs: List[TaskEnvelope]) -> None:
        """Accept a manager-pulled batch: one in-flight bookkeeping pass for
        the whole batch; workers then steal tasks from the shared inbox."""
        with self._lock:
            for env in envs:
                env.executor_id = self.executor_id
                self.in_flight[env.task_id] = env
        for env in envs:
            self.inbox.put(env)

    def take_in_flight(self) -> List[TaskEnvelope]:
        """Called by the watchdog after this executor is declared dead."""
        with self._lock:
            tasks = list(self.in_flight.values())
            self.in_flight.clear()
            return tasks

    def running_longer_than(self, seconds: float) -> List[TaskEnvelope]:
        """Straggler candidates: dispatched here and executing for > seconds."""
        now = time.monotonic()
        with self._lock:
            return [
                e
                for e in self.in_flight.values()
                if e.timestamps.exec_start and (now - e.timestamps.exec_start) > seconds
            ]

    # -- internals ----------------------------------------------------------
    def _forward_results(self) -> None:
        """Drain the workers' outbox into ResultBatch frames: block for the
        first result (latency), then sweep whatever else is ready (throughput)
        so the manager pays one queue round-trip per frame, not per result."""
        while self._alive:
            try:
                res = self._outbox.get(timeout=0.02)
            except queue.Empty:
                continue
            results = [res]
            while len(results) < self.result_max_batch:
                try:
                    results.append(self._outbox.get_nowait())
                except queue.Empty:
                    break
            with self._lock:
                for r in results:
                    self.in_flight.pop(r.envelope.task_id, None)
                self.completed += len(results)
            self.metrics.counter("executor.tasks_executed").inc(len(results))
            service_time = self.metrics.histogram("executor.service_time_s")
            for r in results:
                ts = r.envelope.timestamps
                if ts.exec_end and ts.exec_start:
                    service_time.observe(ts.exec_end - ts.exec_start)
            self.result_queue.put(ResultBatch(results=results))

    def _beat_loop(self) -> None:
        while self._alive:
            self.monitor.beat(self.executor_id)
            self.warm_pool.sweep()
            time.sleep(self.heartbeat_interval_s)

    # -- lifecycle ------------------------------------------------------------
    def kill(self) -> None:
        """Simulated node failure: heartbeats stop, in-flight results vanish."""
        self._alive = False
        for w in self.workers:
            w.simulate_failure()

    def suspend(self) -> None:
        """Paper: 'suspend executors to prevent further tasks being scheduled
        to failed executors'. Also the first step of an autoscaler drain."""
        self._suspended = True

    def resume(self) -> None:
        """Undo a suspend — the autoscaler resumes an executor when work
        raced its drain attempt (a suspended-but-live executor is healthy)."""
        self._suspended = False

    def shutdown(self) -> None:
        self._alive = False
        for w in self.workers:
            w.stop()
        for w in self.workers:
            # A worker mid-execution is left to finish and exit on its own
            # (daemon thread): joining it would stall the caller — e.g. the
            # endpoint manager loop releasing a dead block — long enough for
            # the fabric watchdog to declare the whole endpoint dead.
            if not w.busy:
                w.join(timeout=1.0)
        if self.monitor is not None:
            self.monitor.deregister(self.executor_id)

    def stats(self) -> dict:
        return {
            "executor_id": self.executor_id,
            "workers": self.n_workers,
            "idle": self.idle_workers(),
            "queued": self.inbox.qsize(),
            "in_flight": len(self.in_flight),
            "completed": self.completed,
            "warm": self.warm_pool.stats(),
            "accepting": self.accepting(),
        }
