"""Unified client surface: ``wait`` / ``get_result`` over task futures.

The lithops ``wait.py`` shape, aligned with ``concurrent.futures``
semantics so fabric futures compose with stdlib patterns:

    futs = svc.batch_run(fid, payloads)
    done, pending = wait(futs, return_when=ANY_COMPLETED, timeout=5)
    values = get_result(futs, throw_except=False)

``wait`` blocks via done-callbacks (no polling) until the ``return_when``
condition holds — ``ALL_COMPLETED`` (default), ``ANY_COMPLETED`` (at least
one), or ``ALWAYS`` (return immediately with whatever is done) — and returns
the ``(done, not_done)`` partition in input order, like
:func:`concurrent.futures.wait`. A timeout expiry returns the partial
partition rather than raising; ``get_result`` is the strict variant that
raises :class:`TimeoutError`.

Anything future-shaped works: the functions only use ``done()`` /
``exception()`` / ``result()`` / ``add_done_callback()`` (plus
``remove_done_callback`` when available), so stdlib futures mix freely with
:class:`~repro.core.futures.TaskFuture`\\ s in one call.
"""
from __future__ import annotations

import threading
from typing import Any, List, Optional, Sequence, Tuple

ALL_COMPLETED = "ALL_COMPLETED"
ANY_COMPLETED = "ANY_COMPLETED"
ALWAYS = "ALWAYS"

RETURN_WHEN = (ALL_COMPLETED, ANY_COMPLETED, ALWAYS)


def _as_list(fs: Any) -> Tuple[List[Any], bool]:
    """Normalize a single future or an iterable of futures to a list.
    Returns (futures, was_single)."""
    if hasattr(fs, "add_done_callback"):
        return [fs], True
    return list(fs), False


def _exception_of(f: Any) -> Optional[BaseException]:
    """Terminal exception of a *done* future. TaskFuture returns it; a
    stdlib Future raises CancelledError for cancelled — normalize to return."""
    try:
        return f.exception(0)
    except BaseException as exc:  # noqa: BLE001 - done futures only raise cancellation
        return exc


def _raise_first(done: Sequence[Any]) -> None:
    for f in done:
        exc = _exception_of(f)
        if exc is not None:
            raise exc


def wait(
    fs: Any,
    return_when: str = ALL_COMPLETED,
    timeout: Optional[float] = None,
    throw_except: bool = True,
) -> Tuple[List[Any], List[Any]]:
    """Block until the futures in `fs` satisfy `return_when`, then return the
    ``(done, not_done)`` partition (input order preserved).

    With ``throw_except`` (default) the first exception among the done
    futures is re-raised — including :class:`CancelledError` for cancelled
    tasks; pass ``throw_except=False`` to inspect failures yourself. On
    timeout the partial partition is returned (stdlib ``wait`` contract);
    use :func:`get_result` when a timeout should raise instead."""
    if return_when not in RETURN_WHEN:
        raise ValueError(
            f"unknown return_when {return_when!r}; choose from {RETURN_WHEN}"
        )
    futures, _ = _as_list(fs)
    if return_when != ALWAYS and futures:
        target = 1 if return_when == ANY_COMPLETED else len(futures)
        event = threading.Event()
        lock = threading.Lock()
        ndone = [0]

        def _on_done(_f: Any) -> None:
            with lock:
                ndone[0] += 1
                if ndone[0] >= target:
                    event.set()

        for f in futures:
            f.add_done_callback(_on_done)  # already-done futures fire inline
        event.wait(timeout)
        for f in futures:  # detach from the stragglers — no callback leak
            remove = getattr(f, "remove_done_callback", None)
            if remove is not None and not f.done():
                remove(_on_done)
    done = [f for f in futures if f.done()]
    not_done = [f for f in futures if not f.done()]
    if throw_except:
        _raise_first(done)
    return done, not_done


def get_result(
    fs: Any,
    throw_except: bool = True,
    timeout: Optional[float] = None,
) -> Any:
    """Gather results: a single future yields its bare result, an iterable
    yields the ordered result list. Raises :class:`TimeoutError` when not
    everything completes within `timeout`. With ``throw_except=False`` a
    failed (or cancelled) future contributes ``None`` instead of raising."""
    futures, single = _as_list(fs)
    _, not_done = wait(
        futures, return_when=ALL_COMPLETED, timeout=timeout, throw_except=False
    )
    if not_done:
        raise TimeoutError(
            f"{len(not_done)} of {len(futures)} tasks incomplete after {timeout}s"
        )
    results: List[Any] = []
    for f in futures:
        exc = _exception_of(f)
        if exc is not None:
            if throw_except:
                raise exc
            results.append(None)
        else:
            results.append(f.result(0))
    return results[0] if single else results
