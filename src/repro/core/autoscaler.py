"""Policy-driven elastic autoscaling (paper §5.4 "managed elasticity").

funcX endpoints grow and shrink pilot-job blocks to track demand. The seed's
heuristic ("scale out by 1 when the queue is deep") had no scale-in and no
policy surface; this module makes provisioning a first-class subsystem, the
way the follow-up funcX papers (arXiv:2005.04215, arXiv:2209.11631) treat it:

- A :class:`ScalingPolicy` computes *desired blocks* from a
  :class:`ScalingObservation` (queue depth, in-flight tasks, live blocks,
  observed latency). Two built-ins:

  * :class:`TargetQueueDepthPolicy` — size the pool so each worker carries at
    most ``target_tasks_per_worker`` queued+running tasks.
  * :class:`LatencySLOPolicy` — scale out while observed p95 latency exceeds
    the SLO; scale in only when comfortably under it *and* idle.

- The :class:`Autoscaler` clamps desired blocks to the provider's
  ``ProviderSpec.min_blocks``/``max_blocks``, scales **out** in proportional
  steps (``step_fraction`` of the gap per tick, so a big burst converges in a
  few heartbeats without overshooting), and scales **in** at most one block
  per tick after a ``cooldown_s`` quiet period — draining the chosen executor
  (suspend, verify no in-flight work, release) so no task is ever lost to a
  scale-in. The cool-down timer resets on every scale-out, which prevents
  flapping under oscillating load.

- Every decision is published through the shared :class:`MetricsRegistry`
  (``autoscaler.*`` gauges/counters, catalog in docs/scaling.md), the same
  registry the Forwarder's ``latency_aware`` routing reads — telemetry and
  control consume one set of numbers.

The watchdog's replacement path also routes through :meth:`replace_block`:
the dead block is *released* from the provider before a replacement is
requested, so repeated failures can no longer leak dead blocks into the
provider's bookkeeping or exceed the ``max_blocks`` ceiling.
"""
from __future__ import annotations

import abc
import math
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Optional

from .metrics import MetricsRegistry
from .provider import Provider


@dataclass
class ScalingObservation:
    """One heartbeat's view of endpoint load, fed to the policy."""

    queue_depth: int = 0
    outstanding: int = 0          # dispatched-but-unfinished across executors
    blocks: int = 0               # live (accepting) blocks
    workers_per_block: int = 1
    p95_latency_s: Optional[float] = None

    @property
    def demand(self) -> int:
        return self.queue_depth + self.outstanding


@dataclass
class ScalingDecision:
    """What the autoscaler decided on one tick (kept in a bounded history
    and mirrored into the metrics registry)."""

    at: float
    action: str                   # "scale_out" | "scale_in" | "hold"
    current: int
    desired: int
    delta: int = 0
    reason: str = ""
    observation: ScalingObservation = field(default_factory=ScalingObservation)


class ScalingPolicy(abc.ABC):
    """Maps an observation to a raw desired block count (pre-clamp)."""

    name = "abstract"

    @abc.abstractmethod
    def desired_blocks(self, obs: ScalingObservation) -> int:
        ...


class TargetQueueDepthPolicy(ScalingPolicy):
    """Provision so each worker carries at most `target_tasks_per_worker`
    queued+running tasks. Zero demand ⇒ zero blocks (the autoscaler clamps
    to ``min_blocks``)."""

    name = "queue_depth"

    def __init__(self, target_tasks_per_worker: float = 2.0):
        if target_tasks_per_worker <= 0:
            raise ValueError("target_tasks_per_worker must be positive")
        self.target_tasks_per_worker = target_tasks_per_worker

    def desired_blocks(self, obs: ScalingObservation) -> int:
        if obs.demand <= 0:
            return 0
        workers_needed = obs.demand / self.target_tasks_per_worker
        return max(1, math.ceil(workers_needed / max(1, obs.workers_per_block)))


class LatencySLOPolicy(ScalingPolicy):
    """Hold p95 task latency under an SLO: scale out (half again the current
    pool) while p95 breaches `slo_s` under demand; drain one block per tick
    while idle. Idleness dominates the latency signal — the p95 window
    freezes when traffic stops, so a stale breach sample must never pin an
    idle endpoint at max_blocks."""

    name = "latency_slo"

    def __init__(self, slo_s: float):
        if slo_s <= 0:
            raise ValueError("slo_s must be positive")
        self.slo_s = slo_s

    def desired_blocks(self, obs: ScalingObservation) -> int:
        if obs.blocks == 0:
            # bootstrap: no block will ever produce a latency signal, so
            # demand alone must bring the pool back from zero
            return 1 if obs.demand else 0
        if obs.demand == 0:
            return obs.blocks - 1  # idle: drain toward min_blocks
        if obs.p95_latency_s is not None and obs.p95_latency_s > self.slo_s:
            return obs.blocks + max(1, math.ceil(obs.blocks * 0.5))
        return obs.blocks


def make_policy(policy, **kwargs) -> ScalingPolicy:
    """Resolve a policy spec: a ScalingPolicy instance passes through; the
    strings "queue_depth" / "latency_slo" build the matching built-in."""
    if isinstance(policy, ScalingPolicy):
        return policy
    if policy == "queue_depth":
        return TargetQueueDepthPolicy(kwargs.get("target_tasks_per_worker", 2.0))
    if policy == "latency_slo":
        return LatencySLOPolicy(kwargs.get("latency_slo_s", 1.0))
    raise ValueError(f"unknown scaling policy {policy!r}")


class Autoscaler:
    """Drives a Provider's block count from policy decisions.

    `host` is the endpoint-shaped owner of the blocks; the autoscaler needs
    three things from it (duck-typed so tests can fake it):

    - ``observe() -> ScalingObservation``
    - ``select_idle_block() -> Optional[(block_id, executor)]`` — a candidate
      whose executor has no queued or in-flight work; the executor must
      support ``suspend()``/``resume()`` and expose ``in_flight`` +
      ``queued_tasks()`` (backlog across its container pools).
    - ``release_block(block_id) -> None`` — drop the executor from the
      host's tables and ``scale_in`` the block at the provider.
    """

    def __init__(
        self,
        provider: Provider,
        host,
        policy="queue_depth",
        cooldown_s: float = 30.0,
        step_fraction: float = 0.5,
        metrics: Optional[MetricsRegistry] = None,
        name: str = "",
        clock: Callable[[], float] = time.monotonic,
        history: int = 256,
        **policy_kwargs,
    ):
        self.provider = provider
        self.host = host
        self.policy = make_policy(policy, **policy_kwargs)
        self.cooldown_s = cooldown_s
        self.step_fraction = step_fraction
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.name = name
        self.clock = clock
        self._lock = threading.Lock()
        # arm the cooldown at birth: the operator's init_blocks survive at
        # least one quiet period before the first scale-in can touch them
        self._last_scale_out = self.clock()
        self._last_scale_in = -math.inf
        self.history: Deque[ScalingDecision] = deque(maxlen=history)
        self.scale_out_events = 0
        self.scale_in_events = 0
        self.replacements = 0
        self.ceiling_denials = 0

    # -- bounds ------------------------------------------------------------
    @property
    def min_blocks(self) -> int:
        return self.provider.spec.min_blocks

    @property
    def max_blocks(self) -> int:
        return self.provider.spec.max_blocks

    def current_blocks(self) -> int:
        return self.provider.status()["blocks"]

    def clamp(self, desired: int) -> int:
        return max(self.min_blocks, min(self.max_blocks, desired))

    # -- the control loop entry point --------------------------------------
    def tick(self, obs: Optional[ScalingObservation] = None) -> ScalingDecision:
        """One heartbeat of the control loop: observe → decide → act.
        Serialized by a lock so a slow provider call can't interleave with
        the next heartbeat's decision."""
        with self._lock:
            if obs is None:
                obs = self.host.observe()
            now = self.clock()
            desired = self.clamp(self.policy.desired_blocks(obs))
            current = self.current_blocks()
            decision = ScalingDecision(
                at=now, action="hold", current=current, desired=desired,
                observation=obs,
            )
            if desired > current:
                gap = desired - current
                step = max(1, math.ceil(gap * self.step_fraction))
                step = min(step, self.max_blocks - current)
                created = self.provider.scale_out(step)
                decision.action = "scale_out"
                decision.delta = len(created)
                decision.reason = (
                    f"demand={obs.demand} desired={desired} step={step}"
                )
                if created:
                    self._last_scale_out = now
                    self.scale_out_events += 1
                    self.metrics.counter("autoscaler.scale_out_events").inc()
            elif desired < current and current > self.min_blocks:
                quiet_since = max(self._last_scale_out, self._last_scale_in)
                if now - quiet_since < self.cooldown_s:
                    decision.reason = "cooldown"
                else:
                    released = self._drain_one_idle_block()
                    if released:
                        decision.action = "scale_in"
                        decision.delta = -1
                        decision.reason = f"idle, desired={desired}"
                        self._last_scale_in = now
                        self.scale_in_events += 1
                        self.metrics.counter("autoscaler.scale_in_events").inc()
                    else:
                        decision.reason = "no idle block to drain"
            self.history.append(decision)
            self._publish(decision)
            return decision

    def _drain_one_idle_block(self) -> bool:
        """Drain-then-release: suspend the candidate executor so the
        scheduler stops feeding it, re-verify it is still empty (a dispatch
        may have raced the selection), and only then release the block. An
        executor with any outstanding work is resumed, never killed."""
        cand = self.host.select_idle_block()
        if cand is None:
            return False
        block_id, ex = cand
        ex.suspend()
        if len(ex.in_flight) or ex.queued_tasks():
            ex.resume()
            return False
        self.host.release_block(block_id)
        return True

    # -- watchdog replacement path ------------------------------------------
    def replace_block(self, dead_block_id: Optional[str]) -> bool:
        """Replace a failed block: release the corpse first (so dead blocks
        never accumulate in the provider's bookkeeping), then request one
        replacement if — and only if — the ceiling allows it. Returns True
        when a replacement block was provisioned.

        The corpse is released, not scaled in: a false-positive death (a
        heartbeat stall, which the Forwarder's resurrection path explicitly
        anticipates) must leave the executor running so its late results
        still resolve futures — only genuine scale-in tears blocks down."""
        with self._lock:
            if dead_block_id is not None:
                self.provider.release([dead_block_id])
            if self.current_blocks() >= self.max_blocks:
                self.ceiling_denials += 1
                self.metrics.counter("autoscaler.ceiling_denials").inc()
                return False
            created = self.provider.scale_out(1)
            if created:
                self.replacements += 1
                self.metrics.counter("autoscaler.replacements").inc()
                self._last_scale_out = self.clock()
            return bool(created)

    # -- telemetry ----------------------------------------------------------
    def _publish(self, decision: ScalingDecision) -> None:
        labels = {"endpoint": self.name} if self.name else None
        m = self.metrics
        m.gauge("autoscaler.desired_blocks", labels).set(decision.desired)
        m.gauge("autoscaler.blocks", labels).set(self.current_blocks())
        m.gauge("autoscaler.queue_depth", labels).set(
            decision.observation.queue_depth
        )

    def stats(self) -> dict:
        with self._lock:
            last = self.history[-1] if self.history else None
            return {
                "policy": self.policy.name,
                "min_blocks": self.min_blocks,
                "max_blocks": self.max_blocks,
                "blocks": self.current_blocks(),
                "cooldown_s": self.cooldown_s,
                "scale_out_events": self.scale_out_events,
                "scale_in_events": self.scale_in_events,
                "replacements": self.replacements,
                "ceiling_denials": self.ceiling_denials,
                "last_decision": (
                    {
                        "action": last.action,
                        "desired": last.desired,
                        "current": last.current,
                        "reason": last.reason,
                    }
                    if last
                    else None
                ),
            }
