"""Task envelopes and futures.

funcX invocations are asynchronous: ``run()`` returns a :class:`TaskFuture`
whose result is delivered by the endpoint's manager loop. Every task carries a
timestamp trail so the paper's latency decomposition (Fig. 5: t_c / t_w / t_m /
t_e) can be reconstructed per invocation.
"""
from __future__ import annotations

import enum
import itertools
import threading
import time
import uuid
from concurrent.futures import CancelledError
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple


class TaskState(enum.Enum):
    PENDING = "pending"
    QUEUED = "queued"          # accepted by service, waiting in endpoint queue
    DISPATCHED = "dispatched"  # assigned to an executor
    RUNNING = "running"        # picked up by a worker
    SUCCESS = "success"
    FAILED = "failed"
    LOST = "lost"              # executor died while task in flight
    MEMOIZED = "memoized"      # served from the memo cache
    CANCELLED = "cancelled"    # client cancelled before a result arrived


_task_counter = itertools.count()


def new_task_id() -> str:
    return f"task-{next(_task_counter)}-{uuid.uuid4().hex[:8]}"


@dataclass
class Timestamps:
    """Wall-clock trail. All fields are ``time.monotonic()`` values."""

    client_submit: float = 0.0     # client called run()
    service_in: float = 0.0        # service accepted the request
    endpoint_in: float = 0.0       # endpoint queue insertion
    dispatched: float = 0.0        # manager assigned to an executor
    exec_start: float = 0.0        # worker began executing
    exec_end: float = 0.0          # worker finished executing
    result_ready: float = 0.0      # future completed

    def breakdown(self) -> dict:
        """Paper Fig. 5 decomposition (seconds).

        t_c: client <-> service round-trip overhead
        t_w: service routing (accept -> endpoint queue)
        t_m: endpoint/manager latency (queue + dispatch + worker pickup)
        t_e: function execution time
        """
        t_e = max(0.0, self.exec_end - self.exec_start)
        t_m = max(0.0, self.exec_start - self.endpoint_in)
        t_w = max(0.0, self.endpoint_in - self.service_in)
        total = max(0.0, self.result_ready - self.client_submit)
        t_c = max(0.0, total - t_w - t_m - t_e)
        return {"t_c": t_c, "t_w": t_w, "t_m": t_m, "t_e": t_e, "total": total}


@dataclass
class TaskEnvelope:
    """The unit that travels service -> endpoint -> executor -> worker."""

    task_id: str
    function_id: str
    payload: bytes                      # serialized input document
    container: str = "default"          # container type / warm-cache variant key
    # Capabilities the executing container pool must provide (resolved from
    # the RegisteredFunction's ResourceSpec at submission). The Forwarder and
    # Scheduler route only where these are satisfied; a task no live endpoint
    # can satisfy fails fast with a CapabilityError.
    requirements: Tuple[str, ...] = ()
    memoize: bool = False
    max_retries: int = 2
    retries: int = 0
    speculative_of: Optional[str] = None  # task_id this is a straggler-duplicate of
    timestamps: Timestamps = field(default_factory=Timestamps)
    # Filled in by the endpoint:
    executor_id: Optional[str] = None
    # Frame identity: set when this task travels inside a TaskBatch. A retry
    # is a fresh single-task attempt, so clone_for_retry() drops it.
    batch_id: Optional[str] = None
    # Soft routing preference (workflow warm-affinity: a node's children
    # prefer the endpoint holding the parent's warm function). The Forwarder
    # honors it only while the hinted endpoint is live and has spare capacity.
    affinity_hint: Optional[str] = None
    # Session-sticky routing (serving tier): tasks sharing a session_id pin
    # to one endpoint for as long as it stays live — a decode step must land
    # where the session's KV-cache slot lives, so stickiness survives
    # saturation (unlike affinity_hint) and rebinds only on endpoint death,
    # at which point the serving layer re-prefills (cache migration).
    session_id: Optional[str] = None
    # Data fabric (see core/datastore.py): (key, size) of every DataRef the
    # payload carries — the Forwarder's transfer estimator reads sizes without
    # unpacking, and endpoints resolve refs at dispatch when this is
    # non-empty. `spill_store`/`spill_threshold` tell the worker where to
    # spill an oversized *result* so it returns as a ref, not inline bytes.
    data_refs: Tuple[Tuple[str, int], ...] = ()
    spill_store: Optional[str] = None
    spill_threshold: Optional[int] = None
    # Runtime-only handles to the dispatching endpoint's locality caches
    # (raw blobs + decoded values); attached at dispatch and deliberately
    # NOT cloned for retries (a retry may land on a different endpoint,
    # whose own dispatch re-warms them).
    data_cache: Any = None
    data_decoded: Any = None
    # Runtime-only handle to the dispatching endpoint's SiteRuntime (worker
    # SiteRuntime): endpoint-scoped state for site-aware functions (serving
    # hosts live there). Attached at dispatch, never cloned.
    site: Any = None
    # Identity that submitted this task (from TokenAuthority.verify); drives
    # per-tenant quotas and fair-share dequeue in the Forwarder. None when no
    # auth is configured (treated as the shared "anonymous" tenant).
    tenant: Optional[str] = None

    def _clone(self, **overrides) -> "TaskEnvelope":
        """Base for retry/speculation clones. The packed payload is immutable
        wire bytes, so clones alias it (`clone.payload is self.payload`) —
        duplicating a task must never duplicate its payload. Timestamps are
        shared too: the trail describes the one logical task. Runtime-only
        handles (`data_cache`/`data_decoded`/`site`, `executor_id`,
        `batch_id`) are dropped: the clone travels the fabric as a fresh
        attempt.
        """
        fields = dict(
            task_id=self.task_id,
            function_id=self.function_id,
            payload=self.payload,
            container=self.container,
            requirements=self.requirements,
            memoize=self.memoize,
            max_retries=self.max_retries,
            retries=self.retries,
            timestamps=self.timestamps,
            affinity_hint=self.affinity_hint,
            session_id=self.session_id,
            data_refs=self.data_refs,
            spill_store=self.spill_store,
            spill_threshold=self.spill_threshold,
            tenant=self.tenant,
        )
        fields.update(overrides)
        return TaskEnvelope(**fields)

    def clone_for_retry(self) -> "TaskEnvelope":
        return self._clone(retries=self.retries + 1)

    def clone_speculative(self, suffix: str) -> "TaskEnvelope":
        """Straggler-duplicate of this task: same shared payload bytes and
        timestamp trail, id-suffixed so result dedup maps it back to the
        canonical task (`speculative_of`). Never retried on its own — the
        canonical attempt owns the retry budget."""
        return self._clone(
            task_id=f"{self.task_id}{suffix}",
            speculative_of=self.task_id,
            max_retries=0,
        )


class TaskFuture:
    """Thread-safe future for an asynchronous function invocation."""

    def __init__(self, task_id: str):
        self.task_id = task_id
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._state = TaskState.PENDING
        self._result: Any = None
        self._exception: Optional[BaseException] = None
        self.timestamps = Timestamps()
        self._callbacks: list[Callable[["TaskFuture"], None]] = []
        # Stamped by the Forwarder at routing time (and re-stamped on
        # failover): where this task currently lives. Consumers (the workflow
        # engine's warm-affinity hints) treat it as best-effort.
        self.endpoint_id: Optional[str] = None

    # -- producer side -------------------------------------------------
    def set_state(self, state: TaskState) -> None:
        with self._lock:
            if not self._event.is_set():
                self._state = state

    def set_result(self, value: Any, state: TaskState = TaskState.SUCCESS) -> bool:
        """Complete the future. Returns False if already complete (idempotent:
        speculative duplicates race and only the first wins)."""
        with self._lock:
            if self._event.is_set():
                return False
            self._result = value
            self._state = state
            self.timestamps.result_ready = time.monotonic()
            self._event.set()
            callbacks = list(self._callbacks)
        for cb in callbacks:
            cb(self)
        return True

    def set_exception(
        self, exc: BaseException, state: TaskState = TaskState.FAILED
    ) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._exception = exc
            self._state = state
            self.timestamps.result_ready = time.monotonic()
            self._event.set()
            callbacks = list(self._callbacks)
        for cb in callbacks:
            cb(self)
        return True

    # -- consumer side -------------------------------------------------
    @property
    def state(self) -> TaskState:
        with self._lock:
            return self._state

    def done(self) -> bool:
        return self._event.is_set()

    def cancel(self) -> bool:
        """Best-effort cancellation (``concurrent.futures`` shape): resolves
        this future with :class:`CancelledError` unless it already completed.
        The fabric cannot interrupt a remotely-executing function — a late
        result for a cancelled task dedupes against the already-resolved
        future (and counts in ``journal.duplicate_results``)."""
        return self.set_exception(
            CancelledError(self.task_id), state=TaskState.CANCELLED
        )

    def cancelled(self) -> bool:
        with self._lock:
            return self._state is TaskState.CANCELLED

    def running(self) -> bool:
        """stdlib alignment: dispatched to (or executing on) a worker and not
        yet complete."""
        with self._lock:
            return not self._event.is_set() and self._state in (
                TaskState.DISPATCHED, TaskState.RUNNING
            )

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError(f"{self.task_id} not complete after {timeout}s")
        if self._exception is not None:
            raise self._exception
        return self._result

    def exception(self, timeout: Optional[float] = None) -> Optional[BaseException]:
        if not self._event.wait(timeout):
            raise TimeoutError(f"{self.task_id} not complete after {timeout}s")
        return self._exception

    def add_done_callback(self, cb: Callable[["TaskFuture"], None]) -> None:
        run_now = False
        with self._lock:
            if self._event.is_set():
                run_now = True
            else:
                self._callbacks.append(cb)
        if run_now:
            cb(self)

    def remove_done_callback(self, cb: Callable[["TaskFuture"], None]) -> bool:
        """Detach a pending done-callback (workflow cancel: the in-flight task
        keeps running but its completion no longer drives the run). Returns
        True if the callback was found and removed."""
        with self._lock:
            try:
                self._callbacks.remove(cb)
                return True
            except ValueError:
                return False

    def latency_breakdown(self) -> dict:
        return self.timestamps.breakdown()
