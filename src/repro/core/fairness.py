"""Multi-tenant fairness and admission control (funcX federation follow-ups).

The funcX federated-fabric papers describe the hosted service arbitrating many
users over shared endpoint fleets: per-user quotas bound how much of the fabric
any one identity can hold in flight, and the forwarder tier drains competing
users' queues fairly instead of FIFO (a greedy tenant's 10^6-task backlog must
not add its full drain time to a light tenant's p99).

Three pieces, all consumed by :class:`~repro.core.forwarder.Forwarder`:

- :class:`FairnessPolicy` — the knobs: per-tenant quota (max outstanding tasks
  before admission rejects with ``retry_after``) and weight (fair-share ratio),
  with defaults for unknown tenants. Binds to a
  :class:`~repro.core.auth.TokenAuthority` so quotas/weights declared on
  tenant profiles (``set_tenant_profile``) apply fabric-wide.
- :class:`TenantLedger` — global outstanding-task accounting. One ledger is
  shared by every shard of a :class:`~repro.core.forwarder.ShardedForwarder`
  so a tenant's quota caps its *fabric-wide* footprint, not per-shard.
- :class:`DeficitRoundRobin` — weighted fair queueing across per-tenant
  submit queues. The forwarder's pump drains it with a budget equal to the
  fabric's spare capacity; tasks beyond that stay in their tenant's queue, so
  a light tenant's task is interleaved ahead of a greedy tenant's backlog.

Rejections surface as :class:`AdmissionError` on the task future, carrying
``retry_after`` (seconds) — the client-visible backpressure signal the paper's
hosted service returns instead of queueing unboundedly.
"""
from __future__ import annotations

import threading
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

#: identity used when a task carries no tenant stamp (no auth configured)
ANONYMOUS = "anonymous"


class AdmissionError(RuntimeError):
    """A tenant's outstanding count exceeds its quota; retry later.

    ``retry_after`` estimates (seconds) when quota headroom should free up,
    derived from observed endpoint service latency and the tenant's backlog.
    """

    def __init__(self, tenant: str, quota: int, outstanding: int, retry_after: float):
        super().__init__(
            f"tenant {tenant!r} at quota ({outstanding}/{quota} outstanding); "
            f"retry after {retry_after:.3f}s"
        )
        self.tenant = tenant
        self.quota = quota
        self.outstanding = outstanding
        self.retry_after = retry_after


@dataclass
class FairnessPolicy:
    """Quota/weight knobs for multi-tenant scheduling.

    Precedence for a tenant's quota (weight works the same):
    explicit ``quotas[tenant]`` → the authority's tenant profile →
    ``default_quota``. ``None`` quota means unlimited.
    """

    default_quota: Optional[int] = None   # None = unlimited outstanding
    default_weight: float = 1.0
    quantum: int = 16                     # DRR credits added per round, scaled by weight
    base_retry_after_s: float = 0.05      # retry_after floor when no latency observed
    quotas: Dict[str, int] = field(default_factory=dict)
    weights: Dict[str, float] = field(default_factory=dict)
    _authority: Any = field(default=None, repr=False, compare=False)

    def bind_profiles(self, authority: Any) -> "FairnessPolicy":
        """Consult `authority.tenant_profile(identity)` for per-tenant knobs
        not set explicitly on this policy."""
        self._authority = authority
        return self

    def _profile(self, tenant: str):
        if self._authority is None:
            return None
        getter = getattr(self._authority, "tenant_profile", None)
        return getter(tenant) if getter is not None else None

    def quota_of(self, tenant: str) -> Optional[int]:
        if tenant in self.quotas:
            return self.quotas[tenant]
        prof = self._profile(tenant)
        if prof is not None and prof.quota is not None:
            return prof.quota
        return self.default_quota

    def weight_of(self, tenant: str) -> float:
        if tenant in self.weights:
            return self.weights[tenant]
        prof = self._profile(tenant)
        if prof is not None and prof.weight is not None:
            return prof.weight
        return self.default_weight


class TenantLedger:
    """Fabric-global outstanding-task counts, one entry per tenant.

    Shared by every forwarder shard: admission (`try_admit`) and completion
    (`release`) are single small-lock counter bumps, so the ledger never
    becomes the contention point the sharding removed.
    """

    def __init__(self, metrics=None):
        self._lock = threading.Lock()
        self._outstanding: Dict[str, int] = {}
        self.metrics = metrics

    def bind_metrics(self, metrics) -> None:
        self.metrics = metrics

    def try_admit(self, tenant: str, quota: Optional[int]) -> bool:
        """Reserve one outstanding slot for `tenant`; False when at quota."""
        with self._lock:
            cur = self._outstanding.get(tenant, 0)
            if quota is not None and cur >= quota:
                return False
            self._outstanding[tenant] = cur + 1
        if self.metrics is not None:
            self.metrics.gauge("fair.tenant_outstanding", {"tenant": tenant}).set(cur + 1)
        return True

    def release(self, tenant: str) -> None:
        with self._lock:
            cur = self._outstanding.get(tenant, 0)
            nxt = max(0, cur - 1)
            if nxt:
                self._outstanding[tenant] = nxt
            else:
                self._outstanding.pop(tenant, None)
        if self.metrics is not None:
            self.metrics.gauge("fair.tenant_outstanding", {"tenant": tenant}).set(nxt)

    def outstanding(self, tenant: str) -> int:
        with self._lock:
            return self._outstanding.get(tenant, 0)


class DeficitRoundRobin:
    """Weighted fair dequeue across per-tenant queues (classic DRR).

    Each drain round grants every backlogged tenant ``weight * quantum``
    credits; a tenant dequeues one task per credit. Rounds repeat until the
    caller's budget is spent or all queues are dry, and the tenant rotation
    persists across drains (served tenants move to the back), so over time
    each backlogged tenant's share of dequeues converges to its weight share.
    """

    def __init__(self, policy: FairnessPolicy, metrics=None):
        self.policy = policy
        self.metrics = metrics
        self._lock = threading.Lock()
        self._queues: "OrderedDict[str, Deque[Any]]" = OrderedDict()
        self._deficit: Dict[str, float] = {}
        self._pending = 0

    def __len__(self) -> int:
        return self._pending

    def pending(self, tenant: Optional[str] = None) -> int:
        with self._lock:
            if tenant is None:
                return self._pending
            q = self._queues.get(tenant)
            return len(q) if q is not None else 0

    def enqueue(self, tenant: str, item: Any) -> None:
        with self._lock:
            q = self._queues.get(tenant)
            if q is None:
                q = self._queues[tenant] = deque()
            q.append(item)
            self._pending += 1
            depth = len(q)
        if self.metrics is not None:
            self.metrics.gauge("fair.queue_depth", {"tenant": tenant}).set(depth)

    def drain(self, budget: int) -> List[Any]:
        """Dequeue up to `budget` items, weighted-fairly across tenants."""
        out: List[Any] = []
        rounds = 0
        touched: Dict[str, int] = {}
        with self._lock:
            while self._pending and len(out) < budget:
                rounds += 1
                progressed = False
                for tenant in list(self._queues):
                    q = self._queues[tenant]
                    if not q:
                        continue
                    credit = self._deficit.get(tenant, 0.0)
                    credit += self.policy.weight_of(tenant) * self.policy.quantum
                    while q and credit >= 1.0 and len(out) < budget:
                        out.append(q.popleft())
                        self._pending -= 1
                        credit -= 1.0
                        progressed = True
                    if q:
                        self._deficit[tenant] = credit
                        # move served tenants back so the next drain starts
                        # with whoever waited longest
                        self._queues.move_to_end(tenant)
                    else:
                        # empty queue forfeits its credit (classic DRR: no
                        # banking credits while idle)
                        self._deficit.pop(tenant, None)
                        del self._queues[tenant]
                    touched[tenant] = len(q)
                    if len(out) >= budget:
                        break
                if not progressed:
                    break
        if self.metrics is not None and out:
            self.metrics.counter("fair.drr_rounds").inc(rounds)
            for tenant, depth in touched.items():
                self.metrics.gauge("fair.queue_depth", {"tenant": tenant}).set(depth)
        return out
