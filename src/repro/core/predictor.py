"""Runtime/transfer prediction for data-aware placement (funcX follow-up
work; SNIPPETS.md central-scheduler exemplars).

Three estimators feed the Forwarder's ``eta_aware`` policy:

- :class:`RuntimePredictor` — per-(function, endpoint) rolling average over
  the last N observed runtimes, with a cold-start fallback chain: unseen
  (function, endpoint) pairs borrow the function's cross-endpoint mean, a
  never-seen function predicts ``None`` (the policy then degrades to
  normalized least-outstanding rather than guessing).
- :class:`TransferPredictor` — byte-cost model ``latency + bytes/bandwidth``
  for moving payload bytes (and any DataRef blobs not already resident in an
  endpoint's locality cache) to a candidate endpoint. Observed transfers
  EWMA-update the bandwidth estimate.
- per-endpoint *queue error* — an EWMA of how much actual completion time
  overran the predicted ETA, folded back into both future ETAs and the
  speculation bound so a consistently mis-modeled endpoint is neither
  dog-piled nor spuriously speculated against.

:class:`TaskPredictor` bundles the three behind the surface the Forwarder
consumes: ``eta()`` at routing time, ``record()``/``observe_eta()`` at result
time, and ``overrun_bound()`` for backup-task speculation.
"""
from __future__ import annotations

import threading
from collections import defaultdict, deque
from typing import Deque, Dict, Optional, Tuple

from .metrics import MetricsRegistry

DEFAULT_LAST_N = 10


class RuntimePredictor:
    """Rolling-average runtime model keyed by (function_id, endpoint_id)."""

    def __init__(self, last_n: int = DEFAULT_LAST_N,
                 metrics: Optional[MetricsRegistry] = None):
        if last_n < 1:
            raise ValueError("last_n must be >= 1")
        self.last_n = last_n
        self.metrics = metrics
        self._lock = threading.Lock()
        self._window: Dict[Tuple[str, str], Deque[float]] = {}
        self._total = 0.0
        self._count = 0

    def record(self, function_id: str, endpoint_id: str, runtime_s: float) -> None:
        if runtime_s < 0:
            return
        with self._lock:
            key = (function_id, endpoint_id)
            win = self._window.get(key)
            if win is None:
                win = self._window[key] = deque(maxlen=self.last_n)
            win.append(float(runtime_s))
            self._total += runtime_s
            self._count += 1
        if self.metrics is not None:
            self.metrics.counter("predictor.observations").inc()

    def predict(self, function_id: str, endpoint_id: str) -> Optional[float]:
        """Mean of the last N runtimes for the pair; cold pairs fall back to
        the function's mean across every endpoint; unknown functions return
        None (the caller chooses a cold-start behavior)."""
        with self._lock:
            win = self._window.get((function_id, endpoint_id))
            if win:
                return sum(win) / len(win)
            pooled = [
                v
                for (fid, _eid), w in self._window.items()
                if fid == function_id
                for v in w
            ]
        if self.metrics is not None:
            self.metrics.counter("predictor.cold_starts").inc()
        if pooled:
            return sum(pooled) / len(pooled)
        return None

    def has_history(self, function_id: str, endpoint_id: str) -> bool:
        with self._lock:
            return bool(self._window.get((function_id, endpoint_id)))

    def global_mean(self) -> Optional[float]:
        with self._lock:
            return self._total / self._count if self._count else None


class TransferPredictor:
    """Seconds to move n bytes: ``latency_s + n / bandwidth_bps``. Defaults
    model an in-process fabric (10 GiB/s, 0.1 ms); observed transfers refine
    the bandwidth estimate by EWMA."""

    def __init__(self, bandwidth_bps: float = 10 * 2**30,
                 latency_s: float = 1e-4, alpha: float = 0.25):
        self.bandwidth_bps = float(bandwidth_bps)
        self.latency_s = float(latency_s)
        self.alpha = alpha
        self._lock = threading.Lock()

    def estimate(self, n_bytes: int) -> float:
        if n_bytes <= 0:
            return 0.0
        with self._lock:
            return self.latency_s + n_bytes / self.bandwidth_bps

    def record(self, n_bytes: int, seconds: float) -> None:
        if n_bytes <= 0 or seconds <= 0:
            return
        observed = n_bytes / seconds
        with self._lock:
            self.bandwidth_bps = (
                self.alpha * observed + (1 - self.alpha) * self.bandwidth_bps
            )


class TaskPredictor:
    """The Forwarder-facing bundle: runtime + transfer models plus the
    per-endpoint queue-error EWMA."""

    def __init__(
        self,
        last_n: int = DEFAULT_LAST_N,
        metrics: Optional[MetricsRegistry] = None,
        transfer: Optional[TransferPredictor] = None,
        queue_error_alpha: float = 0.3,
    ):
        self.metrics = metrics
        self.runtime = RuntimePredictor(last_n=last_n, metrics=metrics)
        self.transfer = transfer if transfer is not None else TransferPredictor()
        self.queue_error_alpha = queue_error_alpha
        self._qlock = threading.Lock()
        self._queue_error: Dict[str, float] = defaultdict(float)

    def bind_metrics(self, metrics: MetricsRegistry) -> None:
        self.metrics = metrics
        self.runtime.metrics = metrics

    def queue_error(self, endpoint_id: str) -> float:
        with self._qlock:
            return self._queue_error[endpoint_id]

    def eta(
        self,
        function_id: str,
        endpoint_id: str,
        transfer_bytes: int,
        outstanding: int,
        capacity: int,
    ) -> float:
        """Predicted completion time from now if routed to `endpoint_id`:
        runtime + transfer cost + queue delay + the endpoint's ETA error.
        A cold function contributes zero runtime/queue terms, so cold-start
        scoring reduces to transfer + error — ties broken by the caller."""
        rt = self.runtime.predict(function_id, endpoint_id)
        rt_q = rt if rt is not None else self.runtime.global_mean()
        queue_delay = (
            outstanding * rt_q / max(1, capacity) if rt_q is not None else 0.0
        )
        return (
            (rt or 0.0)
            + self.transfer.estimate(transfer_bytes)
            + queue_delay
            + self.queue_error(endpoint_id)
        )

    def record(self, function_id: str, endpoint_id: str, runtime_s: float) -> None:
        self.runtime.record(function_id, endpoint_id, runtime_s)

    def observe_eta(
        self, endpoint_id: str, predicted_s: float, actual_s: float
    ) -> None:
        """Fold one completed task's (predicted, actual) pair into the
        endpoint's queue-error EWMA. Only overruns accumulate — the error
        term is a pessimism correction, not a bonus for finishing early."""
        err = max(0.0, actual_s - predicted_s)
        with self._qlock:
            prev = self._queue_error[endpoint_id]
            self._queue_error[endpoint_id] = (
                self.queue_error_alpha * err
                + (1 - self.queue_error_alpha) * prev
            )
        if self.metrics is not None:
            self.metrics.histogram("predictor.eta_error_s").observe(err)

    def overrun_bound(
        self, endpoint_id: str, predicted_s: float,
        factor: float, min_age_s: float,
    ) -> float:
        """Age after which an in-flight task counts as overrunning its ETA
        error bound (the Forwarder then launches a backup copy)."""
        return max(min_age_s, predicted_s * factor + self.queue_error(endpoint_id))

    def stats(self) -> dict:
        with self._qlock:
            qerr = dict(self._queue_error)
        return {
            "observations": self.runtime._count,
            "global_mean_runtime_s": self.runtime.global_mean(),
            "bandwidth_bps": self.transfer.bandwidth_bps,
            "queue_error_s": qerr,
        }
