"""Forwarder: the federated multi-endpoint fabric tier.

The follow-up funcX papers (arXiv:2005.04215, arXiv:2209.11631) make the
Forwarder the central abstraction: a service-side component that owns the
registry of *endpoints* (not executors), tracks their health and observed
performance, and routes every task to some endpoint "without regard for the
physical resource location". This module generalizes the per-executor
policies in :mod:`repro.core.scheduler` one tier up:

- ``random``: uniform choice among live endpoints (paper-faithful baseline).
- ``least_outstanding``: fewest tasks currently routed-but-unfinished.
- ``latency_aware``: lowest EWMA of observed endpoint latency; unmeasured
  endpoints are explored first.
- ``warm_affinity``: prefer endpoints holding a warm executable for the
  task's (function, container), tie-broken by least outstanding.

The Forwarder also runs a liveness watchdog over endpoint heartbeats: when an
endpoint dies mid-task (``Endpoint.kill()`` or a hung manager loop), every
outstanding task routed there is failed over to a surviving endpoint.
``TaskFuture.set_result`` is idempotent, so a false-positive death detection
degrades into a speculative duplicate — first result wins — and a
false-positive endpoint is resurrected once its heartbeat resumes.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .futures import TaskEnvelope, TaskFuture

ENDPOINT_POLICIES = ("random", "least_outstanding", "latency_aware", "warm_affinity")


@dataclass
class EndpointRecord:
    """Forwarder-side bookkeeping for one registered endpoint."""

    endpoint: object                     # Endpoint-shaped: see FakeEndpoint in tests
    outstanding: Dict[str, TaskEnvelope] = field(default_factory=dict)
    latency_ewma: Optional[float] = None  # observed endpoint-tier latency (s)
    routed: int = 0
    completed: int = 0
    dead: bool = False


class Forwarder:
    def __init__(
        self,
        policy: str = "least_outstanding",
        seed: Optional[int] = None,
        ewma_alpha: float = 0.25,
        liveness_threshold_s: float = 2.0,
        watchdog_interval_s: float = 0.05,
        failover: bool = True,
    ):
        if policy not in ENDPOINT_POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; choose from {ENDPOINT_POLICIES}"
            )
        self.policy = policy
        self.ewma_alpha = ewma_alpha
        self.liveness_threshold_s = liveness_threshold_s
        self.watchdog_interval_s = watchdog_interval_s
        self.failover = failover
        self.failovers = 0
        self.orphaned = 0  # tasks that died with no surviving endpoint

        self._rng = random.Random(seed)
        self._records: Dict[str, EndpointRecord] = {}
        self._futures: Dict[str, TaskFuture] = {}
        self._lock = threading.RLock()
        self._alive = True
        self._watchdog = threading.Thread(
            target=self._watchdog_loop, name="forwarder/watchdog", daemon=True
        )
        self._watchdog.start()

    # -- endpoint registry ---------------------------------------------------
    def register(self, endpoint) -> str:
        with self._lock:
            self._records[endpoint.endpoint_id] = EndpointRecord(endpoint=endpoint)
        return endpoint.endpoint_id

    def deregister(self, endpoint_id: str) -> None:
        with self._lock:
            self._records.pop(endpoint_id, None)

    def endpoint_ids(self) -> List[str]:
        with self._lock:
            return list(self._records)

    def endpoints(self) -> Dict[str, object]:
        """Registered endpoints by id (the single source of truth)."""
        with self._lock:
            return {eid: rec.endpoint for eid, rec in self._records.items()}

    def _is_live(self, rec: EndpointRecord) -> bool:
        if rec.dead:
            return False
        is_alive = getattr(rec.endpoint, "is_alive", None)
        return is_alive(self.liveness_threshold_s) if is_alive else True

    def _live_records(self) -> List[EndpointRecord]:
        return [r for r in self._records.values() if self._is_live(r)]

    def live_count(self) -> int:
        with self._lock:
            return len(self._live_records())

    # -- routing -------------------------------------------------------------
    def choose(self, env: TaskEnvelope):
        """Pick a live endpoint for `env` under the configured policy.
        Returns None when no endpoint is live."""
        with self._lock:
            live = self._live_records()
            if not live:
                return None
            if self.policy == "random":
                rec = self._rng.choice(live)
            elif self.policy == "least_outstanding":
                rec = min(live, key=lambda r: (len(r.outstanding), r.routed))
            elif self.policy == "latency_aware":
                unmeasured = [r for r in live if r.latency_ewma is None]
                if unmeasured:  # explore before exploiting
                    rec = min(unmeasured, key=lambda r: (len(r.outstanding), r.routed))
                else:
                    # backlog-weighted EWMA: raw EWMA lags behind a burst, so
                    # scale by outstanding/capacity to avoid dogpiling the
                    # endpoint that last looked fastest
                    def score(r):
                        backlog = len(r.outstanding) / max(1, r.endpoint.capacity())
                        return (r.latency_ewma * (1.0 + backlog), len(r.outstanding))

                    rec = min(live, key=score)
            elif self.policy == "warm_affinity":
                key = (env.function_id, env.container)
                warm = [
                    r for r in live
                    if r.endpoint.has_warm(key)
                    and len(r.outstanding) < max(1, r.endpoint.capacity())
                ]
                # saturated-warm spills to cold endpoints (which then warm up)
                pool = warm or live
                rec = min(pool, key=lambda r: (len(r.outstanding), r.routed))
            else:  # pragma: no cover
                raise AssertionError(self.policy)
            return rec.endpoint

    def submit(
        self,
        env: TaskEnvelope,
        future: TaskFuture,
        endpoint_id: Optional[str] = None,
    ) -> str:
        """Route `env` to an endpoint (pinned when `endpoint_id` is given) and
        track it until its future completes. Returns the chosen endpoint id."""
        with self._lock:
            if endpoint_id is not None:
                rec = self._records.get(endpoint_id)
                if rec is None:
                    raise KeyError(f"unknown endpoint {endpoint_id!r}; register one first")
                if not self._is_live(rec):
                    rec = None  # pinned endpoint died: fall back to policy routing
            else:
                rec = None
            if rec is None:
                live = self._live_records()
                if not live:
                    raise RuntimeError("no live endpoints registered with the forwarder")
                ep = self.choose(env)
                rec = self._records[ep.endpoint_id]
            rec.outstanding[env.task_id] = env
            rec.routed += 1
            self._futures[env.task_id] = future
            endpoint = rec.endpoint
        future.add_done_callback(lambda f, tid=env.task_id: self._on_done(tid, f))
        endpoint.submit(env, future)
        return endpoint.endpoint_id

    def _on_done(self, task_id: str, future: TaskFuture) -> None:
        with self._lock:
            self._futures.pop(task_id, None)
            for rec in self._records.values():
                if task_id in rec.outstanding:
                    rec.outstanding.pop(task_id)
                    if future.exception(0) is None:
                        rec.completed += 1
                        ts = future.timestamps
                        if ts.result_ready and ts.endpoint_in:
                            lat = max(0.0, ts.result_ready - ts.endpoint_in)
                            if rec.latency_ewma is None:
                                rec.latency_ewma = lat
                            else:
                                rec.latency_ewma = (
                                    self.ewma_alpha * lat
                                    + (1 - self.ewma_alpha) * rec.latency_ewma
                                )
                    break

    # -- capacity-proportional sharding ---------------------------------------
    def shard(self, n: int) -> List[Tuple[str, int]]:
        """Split an n-task fan-out across live endpoints proportional to their
        advertised capacity (largest-remainder allocation)."""
        with self._lock:
            live = self._live_records()
            if not live:
                raise RuntimeError("no live endpoints registered with the forwarder")
            caps = [max(1, rec.endpoint.capacity()) for rec in live]
            ids = [rec.endpoint.endpoint_id for rec in live]
        total = sum(caps)
        quotas = [n * c / total for c in caps]
        counts = [int(q) for q in quotas]
        remainder = n - sum(counts)
        by_fraction = sorted(
            range(len(ids)), key=lambda i: quotas[i] - counts[i], reverse=True
        )
        for i in by_fraction[:remainder]:
            counts[i] += 1
        return list(zip(ids, counts))

    # -- liveness watchdog + failover -----------------------------------------
    def _watchdog_loop(self) -> None:
        while self._alive:
            time.sleep(self.watchdog_interval_s)
            try:
                self.check_endpoints()
            except Exception:  # pragma: no cover - watchdog must never die
                pass

    def check_endpoints(self) -> List[str]:
        """Detect newly-dead endpoints and fail their outstanding tasks over to
        survivors. Returns the ids of endpoints declared dead this call."""
        newly_dead: List[Tuple[EndpointRecord, List[TaskEnvelope]]] = []
        with self._lock:
            for rec in self._records.values():
                if rec.dead:
                    # resurrection: a heartbeat-stall false positive (GIL/CPU
                    # pressure) recovers once the endpoint beats again; a
                    # killed endpoint never does (_alive stays False)
                    is_alive = getattr(rec.endpoint, "is_alive", None)
                    if is_alive is None or is_alive(self.liveness_threshold_s):
                        rec.dead = False
                    continue
                if self._is_live(rec):
                    continue
                rec.dead = True
                stranded = list(rec.outstanding.values())
                rec.outstanding.clear()
                newly_dead.append((rec, stranded))
        dead_ids = []
        for rec, stranded in newly_dead:
            dead_ids.append(rec.endpoint.endpoint_id)
            if not self.failover:
                continue
            for env in stranded:
                self._failover_task(env, rec)
        return dead_ids

    def _failover_task(self, env: TaskEnvelope, source: EndpointRecord) -> None:
        with self._lock:
            future = self._futures.get(env.task_id)
        if future is None or future.done():
            return
        env.executor_id = None
        try:
            with self._lock:
                live = self._live_records()
                if not live:
                    raise RuntimeError("no surviving endpoint for failover")
                ep = self.choose(env)
                rec = self._records[ep.endpoint_id]
                rec.outstanding[env.task_id] = env
                rec.routed += 1
            self.failovers += 1
            ep.submit(env, future)
        except RuntimeError as exc:
            is_alive = getattr(source.endpoint, "is_alive", None)
            if is_alive is not None and is_alive(None):
                # merely stalled, not halted: leave the task with its
                # endpoint — it still owns the future and can complete it.
                # Re-check done under the lock: if it completed since the
                # outstanding map was cleared, _on_done already ran and a
                # re-add would leak a phantom entry forever.
                with self._lock:
                    if not future.done():
                        source.outstanding[env.task_id] = env
                return
            self.orphaned += 1
            future.set_exception(
                RuntimeError(f"task {env.task_id} lost: {exc}")
            )

    # -- lifecycle / stats ----------------------------------------------------
    def shutdown(self) -> None:
        self._alive = False
        self._watchdog.join(timeout=2.0)

    def stats(self) -> dict:
        with self._lock:
            return {
                "policy": self.policy,
                "failovers": self.failovers,
                "orphaned": self.orphaned,
                "endpoints": {
                    eid: {
                        "routed": rec.routed,
                        "completed": rec.completed,
                        "outstanding": len(rec.outstanding),
                        "latency_ewma_s": rec.latency_ewma,
                        "dead": rec.dead,
                        "capacity": rec.endpoint.capacity() if not rec.dead else 0,
                    }
                    for eid, rec in self._records.items()
                },
            }
