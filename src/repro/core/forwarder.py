"""Forwarder: the federated multi-endpoint fabric tier.

The follow-up funcX papers (arXiv:2005.04215, arXiv:2209.11631) make the
Forwarder the central abstraction: a service-side component that owns the
registry of *endpoints* (not executors), tracks their health and observed
performance, and routes every task to some endpoint "without regard for the
physical resource location". This module generalizes the per-executor
policies in :mod:`repro.core.scheduler` one tier up:

- ``random``: uniform choice among live endpoints (paper-faithful baseline).
- ``least_outstanding``: fewest tasks currently routed-but-unfinished.
- ``latency_aware``: lowest EWMA of observed endpoint latency; unmeasured
  endpoints are explored first.
- ``warm_affinity``: prefer endpoints holding a warm executable for the
  task's (function, container), tie-broken by least outstanding.
- ``eta_aware``: lowest predicted completion time — per-(function, endpoint)
  rolling-average runtime + transfer cost for payload/DataRef bytes not
  already resident at the endpoint + queue delay + the endpoint's observed
  ETA-error correction (see :mod:`repro.core.predictor`). Unmeasured
  (function, endpoint) pairs are explored first.

With ``speculation=True`` the watchdog also launches one backup copy of any
task that overruns its ETA error bound (``predicted_eta × factor +
queue_error``) onto a different endpoint. First result wins the shared
future; the loser dedupes in the exactly-once ResultStore
(``journal.duplicate_results``) and the journal's commitment point still
fires once (``journal.duplicate_completions == 0``).

The Forwarder also runs a liveness watchdog over endpoint heartbeats: when an
endpoint dies mid-task (``Endpoint.kill()`` or a hung manager loop), every
outstanding task routed there is failed over to a surviving endpoint.
``TaskFuture.set_result`` is idempotent, so a false-positive death detection
degrades into a speculative duplicate — first result wins — and a
false-positive endpoint is resurrected once its heartbeat resumes.

Two scale tiers sit on top (the federated follow-ups' million-task shape):

- :class:`ShardedForwarder` hash-partitions ``task_id → shard`` over N
  independent ``Forwarder`` instances, each with its own endpoint-record
  view, submit queues, pump, watchdog, and lock — completions on shard A
  never contend with routing on shard B. The single ``Forwarder`` is the
  degenerate one-shard case, so :class:`~repro.core.service.FunctionService`,
  resume/journal, and speculation work unchanged against either.
- Multi-tenant fairness (see :mod:`repro.core.fairness`): with a
  :class:`~repro.core.fairness.FairnessPolicy` attached, submissions pass
  per-tenant quota admission (reject with ``retry_after`` instead of
  unbounded queueing), land in per-tenant queues, and the pump drains them
  deficit-round-robin weighted by tenant — a greedy tenant's backlog cannot
  starve a light tenant's p99.
"""
from __future__ import annotations

import random
import threading
import time
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .containers import CapabilityError
from .fairness import ANONYMOUS, AdmissionError, DeficitRoundRobin, FairnessPolicy, TenantLedger
from .futures import TaskEnvelope, TaskFuture
from .interchange import BatchCoalescer, iter_frames
from .journal import Journal, ResultStore
from .metrics import SIZE_BUCKETS, MetricsRegistry
from .predictor import TaskPredictor

ENDPOINT_POLICIES = (
    "random", "least_outstanding", "latency_aware", "warm_affinity", "eta_aware",
)

_Pair = Tuple[TaskEnvelope, TaskFuture]


def _caps_of(endpoint) -> Optional[frozenset]:
    """An endpoint's advertised capability set, or None when it has no
    ``capabilities()`` surface (test fakes, legacy shims)."""
    caps_fn = getattr(endpoint, "capabilities", None)
    if caps_fn is None:
        return None
    return frozenset(caps_fn())


def _endpoint_satisfies(endpoint, requirements, caps=...) -> bool:
    """Capability check against an endpoint's advertised set. Requirement-free
    tasks run anywhere; an endpoint without a capability surface can't claim
    to satisfy any requirement. Callers routing a batch pass a pre-computed
    `caps` snapshot so the endpoint lock is paid once, not once per task."""
    if not requirements:
        return True
    if caps is ...:
        caps = _caps_of(endpoint)
    return caps is not None and set(requirements) <= caps


class EndpointRecord:
    """Forwarder-side bookkeeping for one registered endpoint.

    The two routing signals — observed latency EWMA and outstanding task
    count — are backed by the shared metrics registry (gauges
    ``forwarder.endpoint_latency_ewma_s`` / ``forwarder.endpoint_outstanding``
    labeled by endpoint), not private fields: ``latency_aware`` routing, the
    autoscaler, and external telemetry all consume the same numbers."""

    def __init__(
        self,
        endpoint,                         # Endpoint-shaped: see FakeEndpoint in tests
        pending: Optional[BatchCoalescer] = None,
        metrics: Optional[MetricsRegistry] = None,
        shard: Optional[str] = None,
    ):
        self.endpoint = endpoint
        self.outstanding: Dict[str, TaskEnvelope] = {}
        self.routed = 0
        self.completed = 0
        self.dead = False
        # Per-endpoint submit queue: routed-but-undelivered (envelope, future)
        # pairs waiting for the pump to coalesce them into a TaskBatch.
        self.pending = pending
        # Gauge label disambiguator: every shard of a ShardedForwarder keeps
        # its own record (and measurement view) of each endpoint in one shared
        # registry; without the label the shards would stomp each other's
        # series.
        self.shard = shard
        # EWMA folds happen outside the forwarder's global lock (completions
        # must not serialize against routing); this tiny per-record lock makes
        # the read-modify-write safe against concurrent completer threads.
        self._stat_lock = threading.Lock()
        metrics = metrics if metrics is not None else MetricsRegistry()
        self._bind_gauges(metrics, reset=True)

    def _bind_gauges(self, metrics: MetricsRegistry, reset: bool) -> None:
        labels = {"endpoint": self.endpoint.endpoint_id}
        if self.shard is not None:
            labels["shard"] = self.shard
        self._ewma_gauge = metrics.gauge(
            "forwarder.endpoint_latency_ewma_s", labels
        )
        self._outstanding_gauge = metrics.gauge(
            "forwarder.endpoint_outstanding", labels
        )
        if reset:
            # a fresh record means fresh measurement state: a deregistered
            # endpoint re-joining must be explored again by latency_aware
            # routing, not shunned on an arbitrarily stale EWMA
            self._ewma_gauge.set(None)
            self._outstanding_gauge.set(0)

    def rebind_metrics(self, metrics: MetricsRegistry) -> None:
        """Move this record's gauges to another registry, carrying the
        current values over."""
        ewma, outstanding = self._ewma_gauge.value, self._outstanding_gauge.value
        self._bind_gauges(metrics, reset=False)
        self._ewma_gauge.set(ewma)
        self._outstanding_gauge.set(outstanding if outstanding is not None else 0)

    @property
    def latency_ewma(self) -> Optional[float]:
        """Observed endpoint-tier latency EWMA (s); None until measured."""
        return self._ewma_gauge.value

    @latency_ewma.setter
    def latency_ewma(self, v: Optional[float]) -> None:
        self._ewma_gauge.set(v)

    def sync_outstanding(self) -> None:
        self._outstanding_gauge.set(len(self.outstanding))

    def observe_latency(self, lat: float, alpha: float) -> None:
        """Fold one observed completion latency into the EWMA. Safe to call
        without the forwarder lock (see `_stat_lock`)."""
        with self._stat_lock:
            cur = self._ewma_gauge.value
            self._ewma_gauge.set(lat if cur is None else alpha * lat + (1 - alpha) * cur)


class SessionRouter:
    """Sticky ``session_id → endpoint_id`` map for serving sessions.

    Session affinity is *harder* than ``affinity_hint``: a bound session
    follows its endpoint even when saturated (migrating would force a
    KV-cache re-prefill, queueing is cheaper) and rebinds only when the
    endpoint dies or deregisters — the serving tier then re-prefills on the
    new endpoint (cache migration). One router is shared across every shard
    of a :class:`ShardedForwarder` so a session's tasks agree on their home
    regardless of which shard their task_ids hash to.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._map: Dict[str, str] = {}

    def lookup(self, session_id: str) -> Optional[str]:
        with self._lock:
            return self._map.get(session_id)

    def bind(self, session_id: str, endpoint_id: str) -> Optional[str]:
        """Bind (or rebind) a session; returns the previous binding."""
        with self._lock:
            prev = self._map.get(session_id)
            self._map[session_id] = endpoint_id
            return prev

    def forget(self, session_id: str) -> None:
        with self._lock:
            self._map.pop(session_id, None)

    def evict_endpoint(self, endpoint_id: str) -> int:
        """Drop every session bound to a dead/deregistered endpoint; their
        next task rebinds under the routing policy."""
        with self._lock:
            stale = [s for s, e in self._map.items() if e == endpoint_id]
            for s in stale:
                del self._map[s]
            return len(stale)

    def __len__(self) -> int:
        with self._lock:
            return len(self._map)


class Forwarder:
    def __init__(
        self,
        policy: str = "least_outstanding",
        seed: Optional[int] = None,
        ewma_alpha: float = 0.25,
        liveness_threshold_s: float = 2.0,
        watchdog_interval_s: float = 0.05,
        failover: bool = True,
        max_batch: int = 64,
        max_delay_s: float = 0.0,
        metrics: Optional[MetricsRegistry] = None,
        journal: Optional[Journal] = None,
        predictor: Optional[TaskPredictor] = None,
        speculation: bool = False,
        speculation_eta_factor: float = 3.0,
        speculation_min_age_s: float = 0.05,
        fairness: Optional[FairnessPolicy] = None,
        tenant_ledger: Optional[TenantLedger] = None,
        shard: Optional[str] = None,
        session_router: Optional[SessionRouter] = None,
    ):
        if policy not in ENDPOINT_POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; choose from {ENDPOINT_POLICIES}"
            )
        self.policy = policy
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # Predictive tier (core/predictor.py): runtime/transfer/queue-error
        # models behind eta_aware routing and ETA-overrun backup speculation.
        # Auto-created when either consumer is enabled.
        if predictor is None and (policy == "eta_aware" or speculation):
            predictor = TaskPredictor(metrics=self.metrics)
        self.predictor = predictor
        if predictor is not None:
            predictor.bind_metrics(self.metrics)
        self.speculation = speculation
        self.speculation_eta_factor = speculation_eta_factor
        self.speculation_min_age_s = speculation_min_age_s
        self.backups_launched = 0
        # Durability tier: an optional write-ahead journal records routing
        # transitions, and the task-id-keyed ResultStore is the exactly-once
        # authority — a task's first terminal outcome is recorded here;
        # replayed/speculated duplicates dedupe (journal.duplicate_results).
        self.journal = journal
        self.results = ResultStore(metrics=self.metrics)
        self.ewma_alpha = ewma_alpha
        self.liveness_threshold_s = liveness_threshold_s
        self.watchdog_interval_s = watchdog_interval_s
        self.failover = failover
        self.failovers = 0
        self.orphaned = 0  # tasks that died with no surviving endpoint
        # Batching knobs: delivered frames hold at most `max_batch` tasks; with
        # `max_delay_s > 0` routed tasks sit in per-endpoint submit queues and
        # a pump thread coalesces them, otherwise delivery is synchronous
        # (a lone submit() is simply a batch of one).
        self.max_batch = max_batch
        self.max_delay_s = max_delay_s
        self.batches_delivered = 0
        self.tasks_delivered = 0
        # Multi-tenant fairness: quota admission at submit, per-tenant queues
        # drained deficit-round-robin by the pump. The ledger may be shared
        # (one ledger across every ShardedForwarder shard → quotas cap a
        # tenant's fabric-wide footprint).
        self.fairness = fairness
        self.shard_label = shard
        if fairness is not None:
            self.ledger = tenant_ledger if tenant_ledger is not None else TenantLedger()
            self.ledger.bind_metrics(self.metrics)
            self._fair: Optional[DeficitRoundRobin] = DeficitRoundRobin(
                fairness, metrics=self.metrics
            )
        else:
            self.ledger = None
            self._fair = None

        # Serving tier: session-sticky routing (may be shared across shards).
        self.sessions = (
            session_router if session_router is not None else SessionRouter()
        )

        self._rng = random.Random(seed)
        self._records: Dict[str, EndpointRecord] = {}
        self._futures: Dict[str, TaskFuture] = {}
        self._task_endpoint: Dict[str, str] = {}  # task_id -> endpoint_id (O(1) _on_done)
        # speculation bookkeeping: task_id -> (routed_at, predicted_eta_s),
        # and the set of task ids that already have a backup copy in flight
        self._eta: Dict[str, Tuple[float, float]] = {}
        self._backed: set = set()
        self._lock = threading.RLock()
        self._alive = True
        self._watchdog = threading.Thread(
            target=self._watchdog_loop, name="forwarder/watchdog", daemon=True
        )
        self._watchdog.start()
        self._pump_event = threading.Event()
        self._pump: Optional[threading.Thread] = None
        # The pump also owns the fair drain, so fairness needs it even with
        # synchronous (max_delay_s == 0) delivery.
        if self.max_delay_s > 0 or self._fair is not None:
            self._pump = threading.Thread(
                target=self._pump_loop, name="forwarder/pump", daemon=True
            )
            self._pump.start()

    # -- endpoint registry ---------------------------------------------------
    def register(self, endpoint) -> str:
        with self._lock:
            self._records[endpoint.endpoint_id] = EndpointRecord(
                endpoint=endpoint,
                pending=BatchCoalescer(self.max_batch, self.max_delay_s),
                metrics=self.metrics,
                shard=self.shard_label,
            )
        if self._fair is not None:
            self._pump_event.set()  # queued tenants may now have capacity
        return endpoint.endpoint_id

    def deregister(self, endpoint_id: str) -> None:
        with self._lock:
            self._records.pop(endpoint_id, None)
        self.sessions.evict_endpoint(endpoint_id)

    def rebind_metrics(self, metrics: MetricsRegistry) -> None:
        """Adopt another registry: future forwarder-tier recordings land in
        `metrics`, every registered record's gauges move over with their
        current values, and already-registered endpoints are re-bound too.
        Counters/histograms accumulated before adoption stay in the old
        registry (adoption normally happens at FunctionService construction,
        before any traffic). Keeps fabric telemetry from splitting across
        registries when a pre-built forwarder is handed to a service."""
        with self._lock:
            self.metrics = metrics
            self.results.metrics = metrics
            if self.predictor is not None:
                self.predictor.bind_metrics(metrics)
            records = list(self._records.values())
        for rec in records:
            rec.rebind_metrics(metrics)
            if hasattr(rec.endpoint, "bind_metrics"):
                rec.endpoint.bind_metrics(metrics)

    def endpoint_ids(self) -> List[str]:
        with self._lock:
            return list(self._records)

    def endpoints(self) -> Dict[str, object]:
        """Registered endpoints by id (the single source of truth)."""
        with self._lock:
            return {eid: rec.endpoint for eid, rec in self._records.items()}

    def _is_live(self, rec: EndpointRecord) -> bool:
        if rec.dead:
            return False
        is_alive = getattr(rec.endpoint, "is_alive", None)
        return is_alive(self.liveness_threshold_s) if is_alive else True

    def _live_records(self) -> List[EndpointRecord]:
        return [r for r in self._records.values() if self._is_live(r)]

    def live_count(self) -> int:
        with self._lock:
            return len(self._live_records())

    # -- routing -------------------------------------------------------------
    def choose(self, env: TaskEnvelope):
        """Pick a live endpoint for `env` under the configured policy.
        Returns None when no endpoint is live."""
        with self._lock:
            live = self._live_records()
            if not live:
                return None
            return self._choose_record(live, env).endpoint

    def _choose_record(
        self,
        live: List[EndpointRecord],
        env: TaskEnvelope,
        caps_cache: Optional[Dict[str, Optional[frozenset]]] = None,
    ) -> EndpointRecord:
        """Policy selection over a pre-computed live list (callers batching
        many tasks pay the liveness scan once, not once per task). Must be
        called with the lock held.

        The capability filter runs before any policy: only endpoints whose
        advertised capability set satisfies the task's requirements are
        candidates, so incapable dispatch is impossible. `caps_cache` (by
        endpoint id) amortizes the endpoint-lock walk across a batch. A task
        no live endpoint satisfies raises :class:`CapabilityError` — the
        caller fails the future fast instead of letting a watchdog time it
        out."""
        if not env.requirements:
            capable = live  # requirement-free: no filter walk on the hot path
        else:
            if caps_cache is None:
                caps_cache = {
                    r.endpoint.endpoint_id: _caps_of(r.endpoint) for r in live
                }
            capable = [
                r for r in live
                if _endpoint_satisfies(
                    r.endpoint, env.requirements,
                    caps_cache.get(r.endpoint.endpoint_id),
                )
            ]
        if not capable:
            self.metrics.counter("container.capability_misses").inc()
            advertised = {
                r.endpoint.endpoint_id: sorted(caps_cache.get(r.endpoint.endpoint_id) or ())
                for r in live
            }
            raise CapabilityError(
                f"no live endpoint satisfies requirements "
                f"{sorted(env.requirements)} for task {env.task_id} "
                f"(function {env.function_id[:12]}…); live endpoints advertise "
                f"{advertised}"
            )
        live = capable
        if env.session_id is not None:
            # Session stickiness (serving tier): a bound session follows its
            # endpoint even at capacity — its KV-cache slot lives there and a
            # move means a re-prefill. Only death/deregistration (the binding
            # was evicted, so lookup misses) falls through to the policy.
            bound = self.sessions.lookup(env.session_id)
            if bound is not None:
                for r in live:
                    if r.endpoint.endpoint_id == bound:
                        self.metrics.counter("forwarder.session_hits").inc()
                        return r
        if env.affinity_hint is not None:
            # Soft warm-affinity (workflow parent→child): prefer the hinted
            # endpoint while it is live with spare capacity; saturation or
            # death falls through to the configured policy.
            for r in live:
                if (
                    r.endpoint.endpoint_id == env.affinity_hint
                    and len(r.outstanding) < max(1, r.endpoint.capacity())
                ):
                    self.metrics.counter("forwarder.affinity_hits").inc()
                    return r
        rec = self._policy_pick(live, env)
        if env.session_id is not None:
            # first task of a session (or its first after failover): bind it
            # here so every subsequent decode step lands on this endpoint
            prev = self.sessions.bind(env.session_id, rec.endpoint.endpoint_id)
            if prev is not None and prev != rec.endpoint.endpoint_id:
                self.metrics.counter("forwarder.session_moves").inc()
        return rec

    def _policy_pick(
        self, live: List[EndpointRecord], env: TaskEnvelope
    ) -> EndpointRecord:
        """The configured policy's choice over capability-filtered live
        records (no session/affinity shortcuts — callers handled those)."""
        if self.policy == "random":
            return self._rng.choice(live)
        if self.policy == "least_outstanding":
            return min(live, key=lambda r: (len(r.outstanding), r.routed))
        if self.policy == "latency_aware":
            unmeasured = [r for r in live if r.latency_ewma is None]
            if unmeasured:  # explore before exploiting
                return min(unmeasured, key=lambda r: (len(r.outstanding), r.routed))
            # backlog-weighted EWMA: raw EWMA lags behind a burst, so
            # scale by outstanding/capacity to avoid dogpiling the
            # endpoint that last looked fastest
            def score(r):
                backlog = len(r.outstanding) / max(1, r.endpoint.capacity())
                return (r.latency_ewma * (1.0 + backlog), len(r.outstanding))

            return min(live, key=score)
        if self.policy == "warm_affinity":
            key = (env.function_id, env.container)
            warm = [
                r for r in live
                if r.endpoint.has_warm(key)
                and len(r.outstanding) < max(1, r.endpoint.capacity())
            ]
            # saturated-warm spills to cold endpoints (which then warm up)
            pool = warm or live
            return min(pool, key=lambda r: (len(r.outstanding), r.routed))
        if self.policy == "eta_aware":
            return self._choose_eta(live, env)
        raise AssertionError(self.policy)  # pragma: no cover

    def _transfer_bytes(self, rec: EndpointRecord, env: TaskEnvelope) -> int:
        """Bytes that must move to run `env` at this endpoint: the inline
        payload plus every DataRef blob not already in its locality cache."""
        inline = len(env.payload) if isinstance(env.payload, (bytes, bytearray)) else 0
        if not env.data_refs:
            return inline
        has_data = getattr(rec.endpoint, "has_data", None)
        miss = sum(
            size for key, size in env.data_refs
            if has_data is None or not has_data(key)
        )
        return inline + miss

    def _choose_eta(
        self, live: List[EndpointRecord], env: TaskEnvelope
    ) -> EndpointRecord:
        """Lowest predicted completion time (runtime + transfer + queue delay
        + ETA-error correction). Unmeasured (function, endpoint) pairs are
        explored first — normalized least-outstanding among them — so the
        runtime model covers every endpoint before exploitation begins. The
        chosen ETA is remembered for speculation's overrun check."""
        pred = self.predictor
        now = time.monotonic()

        def load(r: EndpointRecord) -> float:
            return len(r.outstanding) / max(1, r.endpoint.capacity())

        unmeasured = [
            r for r in live
            if not pred.runtime.has_history(env.function_id, r.endpoint.endpoint_id)
        ]
        if unmeasured:
            rec = min(unmeasured, key=lambda r: (load(r), r.routed))
            eta = pred.eta(
                env.function_id, rec.endpoint.endpoint_id,
                self._transfer_bytes(rec, env),
                len(rec.outstanding), max(1, rec.endpoint.capacity()),
            )
            self._eta[env.task_id] = (now, eta)
            return rec
        best = best_eta = best_key = None
        for r in live:
            eta = pred.eta(
                env.function_id, r.endpoint.endpoint_id,
                self._transfer_bytes(r, env),
                len(r.outstanding), max(1, r.endpoint.capacity()),
            )
            key = (eta, load(r), r.routed)
            if best_key is None or key < best_key:
                best, best_eta, best_key = r, eta, key
        self._eta[env.task_id] = (now, best_eta)
        return best

    def submit(
        self,
        env: TaskEnvelope,
        future: TaskFuture,
        endpoint_id: Optional[str] = None,
    ) -> Optional[str]:
        """Route `env` to an endpoint (pinned when `endpoint_id` is given) and
        track it until its future completes. Returns the chosen endpoint id
        (None when the future was capability-failed instead of routed).
        A single submit travels the batched pipe as a batch of one."""
        return self.submit_many([(env, future)], endpoint_id=endpoint_id)[0]

    def submit_many(
        self,
        pairs: Sequence[_Pair],
        endpoint_id: Optional[str] = None,
    ) -> List[Optional[str]]:
        """Route a batch of (envelope, future) pairs, amortizing registry locks
        and delivering one TaskBatch frame per chosen endpoint. Returns the
        chosen endpoint id for each pair, in order — None for a pair whose
        future was failed fast with a :class:`CapabilityError` (no live
        endpoint, pinned or otherwise, satisfies its requirements).

        With ``max_delay_s > 0`` the routed pairs land in per-endpoint submit
        queues and the pump delivers them (flush-on-size happens inline);
        otherwise delivery is synchronous.

        With a fairness policy attached, each pair first passes quota
        admission (futures beyond the tenant's quota fail fast with
        :class:`~repro.core.fairness.AdmissionError` carrying ``retry_after``)
        and admitted pairs land in per-tenant queues for the pump's
        deficit-round-robin drain — routing is deferred, so every admitted
        pair's chosen id reports as None."""
        pairs = list(pairs)
        if not pairs:
            return []
        if self._fair is None:
            return self._route_many(pairs, endpoint_id)
        admitted = 0
        for env, future in pairs:
            tenant = getattr(env, "tenant", None) or ANONYMOUS
            quota = self.fairness.quota_of(tenant)
            if not self.ledger.try_admit(tenant, quota):
                self.metrics.counter("fair.rejected", {"tenant": tenant}).inc()
                future.set_exception(AdmissionError(
                    tenant=tenant, quota=quota,
                    outstanding=self.ledger.outstanding(tenant),
                    retry_after=self._retry_after(tenant, quota),
                ))
                continue
            # the quota slot frees when the task reaches ANY terminal state —
            # completion, failover loss, cancellation — so the ledger can
            # never leak a slot
            future.add_done_callback(lambda f, t=tenant: self.ledger.release(t))
            self._fair.enqueue(tenant, (env, future, endpoint_id))
            admitted += 1
        if admitted:
            self.metrics.counter("fair.admitted").inc(admitted)
            self._pump_event.set()
        return [None] * len(pairs)

    def _retry_after(self, tenant: str, quota: Optional[int]) -> float:
        """Backpressure hint: observed mean endpoint service latency scaled by
        how deep the tenant's own backlog already is relative to its quota."""
        with self._lock:
            ewmas = [
                r.latency_ewma for r in self._records.values()
                if r.latency_ewma is not None
            ]
        lat = sum(ewmas) / len(ewmas) if ewmas else self.fairness.base_retry_after_s
        backlog = self._fair.pending(tenant)
        return max(
            self.fairness.base_retry_after_s,
            lat * (1.0 + backlog / max(1, quota or 1)),
        )

    def _route_many(
        self,
        pairs: Sequence[_Pair],
        endpoint_id: Optional[str] = None,
    ) -> List[Optional[str]]:
        """The routing core (admission-free): policy choice, bookkeeping,
        journaling, delivery. Fairness-mode pumps call this after the DRR
        drain; without fairness `submit_many` is a straight pass-through."""
        pairs = list(pairs)
        if not pairs:
            return []
        chosen: List[Optional[str]] = []
        routed_pairs: List[_Pair] = []
        rejected: List[Tuple[TaskFuture, CapabilityError]] = []
        deliveries: Dict[str, Tuple[EndpointRecord, List[_Pair]]] = {}
        with self._lock:
            pinned: Optional[EndpointRecord] = None
            pinned_caps: Optional[frozenset] = None
            if endpoint_id is not None:
                pinned = self._records.get(endpoint_id)
                if pinned is None:
                    raise KeyError(f"unknown endpoint {endpoint_id!r}; register one first")
                if not self._is_live(pinned):
                    pinned = None  # pinned endpoint died: fall back to policy routing
                else:
                    pinned_caps = _caps_of(pinned.endpoint)
            live: Optional[List[EndpointRecord]] = None
            caps_cache: Optional[Dict[str, Optional[frozenset]]] = None
            decisions = 0
            for env, future in pairs:
                rec = pinned
                if rec is not None and not _endpoint_satisfies(
                    rec.endpoint, env.requirements, pinned_caps
                ):
                    self.metrics.counter("container.capability_misses").inc()
                    rejected.append((future, CapabilityError(
                        f"pinned endpoint {endpoint_id!r} does not provide "
                        f"{sorted(env.requirements)} required by task {env.task_id}"
                    )))
                    chosen.append(None)
                    continue
                if rec is None:
                    if live is None:  # liveness scan paid once per batch
                        live = self._live_records()
                    if not live:
                        raise RuntimeError(
                            "no live endpoints registered with the forwarder"
                        )
                    if caps_cache is None and env.requirements:
                        # capability snapshot paid once per batch, like the
                        # liveness scan — not once per task under the lock
                        caps_cache = {
                            r.endpoint.endpoint_id: _caps_of(r.endpoint)
                            for r in live
                        }
                    try:
                        rec = self._choose_record(live, env, caps_cache)
                    except CapabilityError as exc:
                        # fail fast through the future: the rest of the batch
                        # still routes (capability misses are per-task)
                        rejected.append((future, exc))
                        chosen.append(None)
                        continue
                    decisions += 1
                elif env.session_id is not None:
                    # a pinned task establishes session residency exactly like
                    # a policy-routed one: the session's next unpinned step
                    # must follow its KV cache to this endpoint
                    self.sessions.bind(env.session_id, rec.endpoint.endpoint_id)
                eid = rec.endpoint.endpoint_id
                rec.outstanding[env.task_id] = env
                rec.routed += 1
                self._futures[env.task_id] = future
                self._task_endpoint[env.task_id] = eid
                future.endpoint_id = eid
                chosen.append(eid)
                routed_pairs.append((env, future))
                deliveries.setdefault(eid, (rec, []))[1].append((env, future))
            self.metrics.counter("forwarder.tasks_routed").inc(len(routed_pairs))
            if decisions:  # one bulk inc, not one per task inside the lock
                self.metrics.counter(
                    "forwarder.routing_decisions", {"policy": self.policy}
                ).inc(decisions)
            for rec, _ in deliveries.values():
                rec.sync_outstanding()
        for future, exc in rejected:
            future.set_exception(exc)
        for env, future in routed_pairs:
            future.add_done_callback(lambda f, tid=env.task_id: self._on_done(tid, f))
        if self.journal is not None:
            # WAL ordering: the routing transition is journaled before the
            # task can reach an endpoint, so a terminal record never precedes
            # its routed record
            for env, future in routed_pairs:
                self.journal.append(
                    "task", "routed",
                    task_id=env.task_id, endpoint_id=future.endpoint_id,
                )
        # deliver via the record captured at routing time: a concurrent
        # deregister() must not strand already-routed tasks undelivered
        for rec, routed in deliveries.values():
            if self.max_delay_s > 0:
                for pair in routed:
                    full = rec.pending.add(pair)
                    if full:  # flush-on-size fires inline
                        self._deliver(rec.endpoint, full)
                self._pump_event.set()
            else:
                self._deliver(rec.endpoint, routed)
        return chosen

    def _deliver(self, endpoint, pairs: List[_Pair]) -> None:
        """Hand routed pairs to `endpoint` as TaskBatch frames of at most
        `max_batch` tasks (per-task submit for endpoints without a batch
        surface, e.g. test fakes)."""
        submit_batch = getattr(endpoint, "submit_batch", None)
        for frame in iter_frames(pairs, self.max_batch):
            with self._lock:
                self.batches_delivered += 1
                self.tasks_delivered += len(frame)
            self.metrics.counter("forwarder.batches_delivered").inc()
            self.metrics.histogram(
                "forwarder.batch_size", buckets=SIZE_BUCKETS
            ).observe(len(frame))
            if submit_batch is not None:
                submit_batch(frame)
            else:
                for env, future in frame.pairs():
                    endpoint.submit(env, future)

    # -- submit-queue pump ----------------------------------------------------
    def _pump_loop(self) -> None:
        interval = min(0.01, max(0.001, self.max_delay_s / 4))
        while self._alive:
            self._pump_event.wait(timeout=interval)
            self._pump_event.clear()
            try:
                self.pump_once()
            except Exception:  # pragma: no cover - pump must never die
                pass

    def pump_once(self, force: bool = False) -> int:
        """Flush per-endpoint submit queues whose deadline has expired (all of
        them when `force`), after draining the fair-share tenant queues when
        fairness is on. Returns the number of tasks delivered."""
        delivered = self._pump_fair(force) if self._fair is not None else 0
        return delivered + self._pump_queues(force)

    def _pump_fair(self, force: bool = False) -> int:
        """Drain the per-tenant queues deficit-round-robin into the router.

        The drain budget is the fabric's spare capacity (Σ max(0, capacity −
        outstanding) over live endpoints): tasks beyond it stay queued by
        tenant, which is the fairness mechanism itself — a light tenant's
        next task is drained ahead of a greedy tenant's backlog instead of
        joining the back of a FIFO. With no live endpoints the budget is 0
        and tenants simply wait. `force` (shutdown) ignores the budget."""
        drained = 0
        while True:
            with self._lock:
                budget = sum(
                    max(0, r.endpoint.capacity() - len(r.outstanding))
                    for r in self._live_records()
                )
            if force:
                budget = max(budget, self._fair.pending())
            if budget <= 0 or not self._fair.pending():
                return drained
            items = self._fair.drain(budget)
            if not items:
                return drained
            by_pin: Dict[Optional[str], List[_Pair]] = {}
            for env, future, pin in items:
                by_pin.setdefault(pin, []).append((env, future))
            for pin, routed in by_pin.items():
                try:
                    self._route_many(routed, endpoint_id=pin)
                except (KeyError, RuntimeError) as exc:
                    # unknown pin / every endpoint died since the budget
                    # check: fail these futures (releasing their quota slots)
                    # rather than dropping them silently
                    for _, future in routed:
                        future.set_exception(exc)
            drained += len(items)
            if not force:
                return drained

    def _pump_queues(self, force: bool = False) -> int:
        now = time.monotonic()
        flushes: List[Tuple[object, List[_Pair]]] = []
        with self._lock:
            for rec in self._records.values():
                if rec.pending is None or not len(rec.pending):
                    continue
                if rec.dead:
                    # late adds racing endpoint death: the watchdog already
                    # failed these tasks over, so drop the stale pairs rather
                    # than delivering to a corpse.
                    rec.pending.flush()
                    continue
                batch = rec.pending.flush() if force else rec.pending.poll(now)
                if batch:
                    flushes.append((rec.endpoint, batch))
        delivered = 0
        for endpoint, batch in flushes:
            self._deliver(endpoint, batch)
            delivered += len(batch)
        return delivered

    def resolve(
        self,
        task_id: str,
        value: Any = None,
        error: Optional[BaseException] = None,
    ) -> bool:
        """Idempotent fabric-level result delivery: complete the future for
        `task_id` unless a terminal outcome is already recorded. Replayed
        completions (journal replay, duplicated ResultBatch frames, restarts)
        dedupe here — counted in ``journal.duplicate_results`` — so a future
        resolves exactly once no matter how many times its result arrives.
        Returns True when this call won the resolution."""
        with self._lock:
            future = self._futures.get(task_id)
        if task_id in self.results or (future is not None and future.done()):
            self.metrics.counter("journal.duplicate_results").inc()
            return False
        if future is None:
            return False  # never routed here (or store already evicted it)
        if error is not None:
            return future.set_exception(error)
        return future.set_result(value)

    def _on_done(
        self, task_id: str, future: TaskFuture, canonical: Optional[str] = None
    ) -> None:
        # the exactly-once authority: the first terminal outcome for this
        # task id is recorded; any later delivery dedupes against the store.
        # A backup copy records under its primary's id (`canonical`), so the
        # speculation loser counts as a duplicate instead of a second task.
        exc = future.exception(0)
        self.results.record(
            canonical or task_id,
            value=None if exc is not None else future.result(0),
            error=exc,
        )
        # Completion hot path: the global lock guards ONLY the map mutations
        # (futures/eta/task→endpoint pops, outstanding decrement). Gauge sync,
        # the EWMA fold, and predictor training run outside it — at scale
        # completer threads must not serialize against routing holding this
        # lock on the other side of the fabric.
        env: Optional[TaskEnvelope] = None
        with self._lock:
            self._futures.pop(task_id, None)
            was_backed = (canonical or task_id) in self._backed
            self._backed.discard(canonical or task_id)
            eta_info = self._eta.pop(task_id, None)
            eid = self._task_endpoint.pop(task_id, None)
            rec = self._records.get(eid) if eid is not None else None
            if rec is not None and task_id in rec.outstanding:
                env = rec.outstanding.pop(task_id)
                if exc is None:
                    rec.completed += 1
        if rec is not None and env is not None:
            rec.sync_outstanding()
            if exc is None:
                ts = future.timestamps
                if ts.result_ready and ts.endpoint_in:
                    rec.observe_latency(
                        max(0.0, ts.result_ready - ts.endpoint_in), self.ewma_alpha
                    )
        if self.predictor is None or eid is None or env is None:
            return
        ts = future.timestamps
        # train the runtime model only on clean, unspeculated primaries: a
        # backed task's shared timestamp trail mixes two copies' clocks
        if (
            canonical is None and not was_backed and exc is None
            and ts.exec_end and ts.exec_start
        ):
            self.predictor.record(
                env.function_id, eid, max(0.0, ts.exec_end - ts.exec_start)
            )
        if canonical is None and eta_info is not None and ts.result_ready:
            routed_at, predicted = eta_info
            self.predictor.observe_eta(
                eid, predicted, max(0.0, ts.result_ready - routed_at)
            )

    # -- ETA-overrun backup speculation ---------------------------------------
    def check_speculation(self) -> int:
        """Launch one backup copy for every unbacked in-flight task older than
        its ETA error bound (``predicted × factor + endpoint queue error``).
        Runs at watchdog cadence when ``speculation=True``; returns how many
        backups launched this call."""
        if self.predictor is None:
            return 0
        now = time.monotonic()
        overdue: List[Tuple[TaskEnvelope, EndpointRecord]] = []
        with self._lock:
            for rec in self._records.values():
                if rec.dead:
                    continue
                for tid, env in rec.outstanding.items():
                    if env.speculative_of or tid in self._backed:
                        continue
                    info = self._eta.get(tid)
                    if info is None:
                        continue  # pinned past the policy: no prediction made
                    routed_at, predicted = info
                    bound = self.predictor.overrun_bound(
                        rec.endpoint.endpoint_id, predicted,
                        self.speculation_eta_factor, self.speculation_min_age_s,
                    )
                    if now - routed_at > bound:
                        overdue.append((env, rec))
        launched = 0
        for env, rec in overdue:
            if self._launch_backup(env, rec):
                launched += 1
        return launched

    def _launch_backup(self, env: TaskEnvelope, source: EndpointRecord) -> bool:
        """Route a speculative duplicate of `env` to a live endpoint other
        than `source`, mapped onto the SAME future. First result wins; the
        loser dedupes (``journal.duplicate_results``). Backups are never
        journaled — the primary's records own the durable identity, so the
        commitment point cannot double-fire."""
        with self._lock:
            future = self._futures.get(env.task_id)
            if future is None or future.done() or env.task_id in self._backed:
                return False
            live = [
                r for r in self._live_records()
                if r is not source
                and _endpoint_satisfies(r.endpoint, env.requirements)
            ]
            if not live:
                return False
            self._backed.add(env.task_id)
            # aliases the primary's packed payload bytes — a backup copy
            # must never duplicate the payload it re-sends
            dup = env.clone_speculative("#eta")
            rec = min(
                live,
                key=lambda r: (
                    len(r.outstanding) / max(1, r.endpoint.capacity()), r.routed
                ),
            )
            rec.outstanding[dup.task_id] = dup
            rec.routed += 1
            rec.sync_outstanding()
            self._futures[dup.task_id] = future
            self._task_endpoint[dup.task_id] = rec.endpoint.endpoint_id
            self.backups_launched += 1
        self.metrics.counter("predictor.backups_launched").inc()
        future.add_done_callback(
            lambda f, tid=dup.task_id, canon=env.task_id: self._on_done(
                tid, f, canonical=canon
            )
        )
        self._deliver(rec.endpoint, [(dup, future)])
        return True

    # -- capacity-proportional sharding ---------------------------------------
    def shard(self, n: int, requirements=()) -> List[Tuple[str, int]]:
        """Split an n-task fan-out across live endpoints proportional to their
        advertised capacity (largest-remainder allocation). With
        `requirements`, only capability-satisfying endpoints receive shards."""
        with self._lock:
            live = self._live_records()
            if not live:
                raise RuntimeError("no live endpoints registered with the forwarder")
            capable = [
                rec for rec in live
                if _endpoint_satisfies(rec.endpoint, requirements)
            ]
            if not capable:
                self.metrics.counter("container.capability_misses").inc()
                raise CapabilityError(
                    f"no live endpoint satisfies requirements "
                    f"{sorted(requirements)} for a {n}-task fan-out"
                )
            caps = [max(1, rec.endpoint.capacity()) for rec in capable]
            ids = [rec.endpoint.endpoint_id for rec in capable]
        total = sum(caps)
        quotas = [n * c / total for c in caps]
        counts = [int(q) for q in quotas]
        remainder = n - sum(counts)
        by_fraction = sorted(
            range(len(ids)), key=lambda i: quotas[i] - counts[i], reverse=True
        )
        for i in by_fraction[:remainder]:
            counts[i] += 1
        return list(zip(ids, counts))

    # -- liveness watchdog + failover -----------------------------------------
    def _watchdog_loop(self) -> None:
        while self._alive:
            time.sleep(self.watchdog_interval_s)
            try:
                self.check_endpoints()
                if self.speculation:
                    self.check_speculation()
            except Exception:  # pragma: no cover - watchdog must never die
                pass

    def check_endpoints(self) -> List[str]:
        """Detect newly-dead endpoints and fail their outstanding tasks over to
        survivors. Returns the ids of endpoints declared dead this call."""
        newly_dead: List[Tuple[EndpointRecord, List[TaskEnvelope]]] = []
        with self._lock:
            for rec in self._records.values():
                if rec.dead:
                    # resurrection: a heartbeat-stall false positive (GIL/CPU
                    # pressure) recovers once the endpoint beats again; a
                    # killed endpoint never does (_alive stays False)
                    is_alive = getattr(rec.endpoint, "is_alive", None)
                    if is_alive is None or is_alive(self.liveness_threshold_s):
                        rec.dead = False
                    continue
                if self._is_live(rec):
                    continue
                rec.dead = True
                evicted = self.sessions.evict_endpoint(rec.endpoint.endpoint_id)
                if evicted:
                    # sticky sessions lose their home with the endpoint; their
                    # next decode step rebinds (and the serving tier
                    # re-prefills the KV cache on the new endpoint)
                    self.metrics.counter("forwarder.session_evictions").inc(evicted)
                stranded = list(rec.outstanding.values())
                rec.outstanding.clear()
                rec.sync_outstanding()
                if rec.pending is not None:
                    # routed-but-undelivered pairs are already in `stranded`
                    # (bookkeeping happens at routing time); just make sure
                    # the pump never delivers them to the corpse.
                    rec.pending.flush()
                newly_dead.append((rec, stranded))
            self.metrics.gauge("forwarder.endpoints_live").set(
                len(self._live_records())
            )
        dead_ids = []
        for rec, stranded in newly_dead:
            dead_ids.append(rec.endpoint.endpoint_id)
            if not self.failover:
                continue
            self._failover_batch(stranded, rec)
        return dead_ids

    def _failover_batch(
        self, stranded: List[TaskEnvelope], source: EndpointRecord
    ) -> None:
        """Re-route every stranded task of a dead endpoint, then re-deliver
        them as whole TaskBatch frames grouped by surviving endpoint (the
        in-flight batch fails over intact rather than task-by-task)."""
        deliveries: Dict[str, List[_Pair]] = {}
        for env in stranded:
            with self._lock:
                future = self._futures.get(env.task_id)
            if future is None or future.done():
                continue
            env.executor_id = None
            try:
                with self._lock:
                    live = self._live_records()
                    if not live:
                        raise RuntimeError("no surviving endpoint for failover")
                    ep = self.choose(env)
                    rec = self._records[ep.endpoint_id]
                    rec.outstanding[env.task_id] = env
                    rec.routed += 1
                    rec.sync_outstanding()
                    self._task_endpoint[env.task_id] = ep.endpoint_id
                    future.endpoint_id = ep.endpoint_id
                self.failovers += 1
                self.metrics.counter("forwarder.failovers").inc()
                if self.journal is not None:
                    self.journal.append(
                        "task", "routed",
                        task_id=env.task_id, endpoint_id=ep.endpoint_id,
                    )
                deliveries.setdefault(ep.endpoint_id, []).append((env, future))
            except RuntimeError as exc:
                is_alive = getattr(source.endpoint, "is_alive", None)
                if is_alive is not None and is_alive(None):
                    # merely stalled, not halted: leave the task with its
                    # endpoint — it still owns the future and can complete it.
                    # Re-check done under the lock: if it completed since the
                    # outstanding map was cleared, _on_done already ran and a
                    # re-add would leak a phantom entry forever.
                    with self._lock:
                        if not future.done():
                            source.outstanding[env.task_id] = env
                            source.sync_outstanding()
                    continue
                self.orphaned += 1
                self.metrics.counter("forwarder.orphaned").inc()
                # a capability miss keeps its type so callers can tell
                # "no capable survivor" from generic endpoint loss
                wrapped: RuntimeError = (
                    CapabilityError(f"task {env.task_id} lost: {exc}")
                    if isinstance(exc, CapabilityError)
                    else RuntimeError(f"task {env.task_id} lost: {exc}")
                )
                future.set_exception(wrapped)
        for eid, routed in deliveries.items():
            with self._lock:
                rec = self._records.get(eid)
            if rec is not None:
                self._deliver(rec.endpoint, routed)

    # -- lifecycle / stats ----------------------------------------------------
    def shutdown(self) -> None:
        if self._pump is not None:
            self.pump_once(force=True)  # don't strand queued tasks
        self._alive = False
        self._pump_event.set()
        self._watchdog.join(timeout=2.0)
        if self._pump is not None:
            self._pump.join(timeout=2.0)

    def stats(self) -> dict:
        with self._lock:
            return {
                "policy": self.policy,
                "shard": self.shard_label,
                "fairness": self._fair is not None,
                "fair_pending": self._fair.pending() if self._fair is not None else 0,
                "failovers": self.failovers,
                "orphaned": self.orphaned,
                "sessions": len(self.sessions),
                "speculation": self.speculation,
                "backups_launched": self.backups_launched,
                "predictor": (
                    self.predictor.stats() if self.predictor is not None else None
                ),
                "max_batch": self.max_batch,
                "max_delay_s": self.max_delay_s,
                "batches_delivered": self.batches_delivered,
                "tasks_delivered": self.tasks_delivered,
                "mean_batch_size": (
                    self.tasks_delivered / self.batches_delivered
                    if self.batches_delivered
                    else 0.0
                ),
                "endpoints": {
                    eid: {
                        "routed": rec.routed,
                        "completed": rec.completed,
                        "outstanding": len(rec.outstanding),
                        "pending": len(rec.pending) if rec.pending is not None else 0,
                        "latency_ewma_s": rec.latency_ewma,
                        "dead": rec.dead,
                        "capacity": rec.endpoint.capacity() if not rec.dead else 0,
                    }
                    for eid, rec in self._records.items()
                },
            }


# -- sharded front ------------------------------------------------------------
def shard_of(task_id: str, n_shards: int) -> int:
    """Stable task→shard partition (crc32: deterministic across processes, so
    a resumed fabric reassigns every journaled task to the same shard)."""
    return zlib.crc32(task_id.encode()) % n_shards


class _ShardedResults:
    """ResultStore facade over a ShardedForwarder: each task's exactly-once
    record lives in its owning shard's store; `prime`/`__contains__` route by
    the same hash the submit path uses, so journal resume primes every
    shard's ResultStore with exactly its own tasks."""

    def __init__(self, owner: "ShardedForwarder"):
        self._owner = owner

    def _store(self, task_id: str) -> ResultStore:
        return self._owner.shard_for(task_id).results

    def prime(self, task_id: str) -> bool:
        return self._store(task_id).prime(task_id)

    def record(self, task_id: str, value: Any = None, error: Any = None) -> bool:
        return self._store(task_id).record(task_id, value=value, error=error)

    def get(self, task_id: str):
        return self._store(task_id).get(task_id)

    def __contains__(self, task_id: str) -> bool:
        return task_id in self._store(task_id)

    def __len__(self) -> int:
        return sum(len(f.results) for f in self._owner.shards)


class ShardedForwarder:
    """N independent :class:`Forwarder` shards behind one Forwarder-shaped
    front (the federated follow-ups' multi-forwarder deployment).

    ``task_id → shard`` is a stable hash partition: every per-task structure
    (future map, outstanding entry, ETA record, result slot) lives in exactly
    one shard, so shards share no per-task state and each keeps its own lock,
    submit queues, pump thread, and watchdog — completions on shard A never
    contend with routing on shard B, which is what lifts the single global
    RLock's throughput ceiling. Endpoints register with every shard; each
    shard learns its own latency/outstanding view of them (gauge series are
    disambiguated with a ``shard`` label).

    The single :class:`Forwarder` is the degenerate one-shard case: the
    surface consumed by :class:`~repro.core.service.FunctionService`
    (register/submit_many/results/journal/resume/shard/stats/shutdown) is
    mirrored here, so services, journal resume, and speculation work
    unchanged against either. With a fairness policy, all shards share one
    :class:`~repro.core.fairness.TenantLedger` so quotas cap a tenant's
    fabric-wide outstanding count, not per-shard.
    """

    def __init__(
        self,
        n_shards: int = 4,
        policy: str = "least_outstanding",
        metrics: Optional[MetricsRegistry] = None,
        journal: Optional[Journal] = None,
        fairness: Optional[FairnessPolicy] = None,
        **forwarder_kwargs,
    ):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.fairness = fairness
        ledger = TenantLedger(metrics=self.metrics) if fairness is not None else None
        self.ledger = ledger
        # One session router across every shard: a session's decode steps
        # hash to different shards by task_id, but must agree on their home.
        self.sessions = SessionRouter()
        self.shards: List[Forwarder] = [
            Forwarder(
                policy=policy,
                metrics=self.metrics,
                journal=journal,
                fairness=fairness,
                tenant_ledger=ledger,
                shard=str(i),
                session_router=self.sessions,
                **forwarder_kwargs,
            )
            for i in range(n_shards)
        ]
        self.results = _ShardedResults(self)

    # -- partition -----------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def shard_index(self, task_id: str) -> int:
        return shard_of(task_id, len(self.shards))

    def shard_for(self, task_id: str) -> Forwarder:
        return self.shards[self.shard_index(task_id)]

    # -- Forwarder-shaped surface ---------------------------------------------
    @property
    def policy(self) -> str:
        return self.shards[0].policy

    @property
    def speculation(self) -> bool:
        return self.shards[0].speculation

    @property
    def journal(self) -> Optional[Journal]:
        return self.shards[0].journal

    @journal.setter
    def journal(self, journal: Optional[Journal]) -> None:
        for fwd in self.shards:
            fwd.journal = journal

    @property
    def liveness_threshold_s(self) -> float:
        return self.shards[0].liveness_threshold_s

    @liveness_threshold_s.setter
    def liveness_threshold_s(self, v: float) -> None:
        for fwd in self.shards:
            fwd.liveness_threshold_s = v

    @property
    def watchdog_interval_s(self) -> float:
        return self.shards[0].watchdog_interval_s

    @watchdog_interval_s.setter
    def watchdog_interval_s(self, v: float) -> None:
        for fwd in self.shards:
            fwd.watchdog_interval_s = v

    @property
    def failovers(self) -> int:
        return sum(f.failovers for f in self.shards)

    @property
    def orphaned(self) -> int:
        return sum(f.orphaned for f in self.shards)

    @property
    def backups_launched(self) -> int:
        return sum(f.backups_launched for f in self.shards)

    def register(self, endpoint) -> str:
        for fwd in self.shards:
            fwd.register(endpoint)
        return endpoint.endpoint_id

    def deregister(self, endpoint_id: str) -> None:
        for fwd in self.shards:
            fwd.deregister(endpoint_id)

    def rebind_metrics(self, metrics: MetricsRegistry) -> None:
        self.metrics = metrics
        if self.ledger is not None:
            self.ledger.bind_metrics(metrics)
        for fwd in self.shards:
            fwd.rebind_metrics(metrics)

    def endpoint_ids(self) -> List[str]:
        return self.shards[0].endpoint_ids()

    def endpoints(self) -> Dict[str, object]:
        return self.shards[0].endpoints()

    def live_count(self) -> int:
        return self.shards[0].live_count()

    def choose(self, env: TaskEnvelope):
        return self.shard_for(env.task_id).choose(env)

    def submit(
        self,
        env: TaskEnvelope,
        future: TaskFuture,
        endpoint_id: Optional[str] = None,
    ) -> Optional[str]:
        return self.shard_for(env.task_id).submit(env, future, endpoint_id=endpoint_id)

    def submit_many(
        self,
        pairs: Sequence[_Pair],
        endpoint_id: Optional[str] = None,
    ) -> List[Optional[str]]:
        """Partition the batch by task-id hash and submit each sub-batch to
        its owning shard, stitching per-pair results back into input order."""
        pairs = list(pairs)
        if not pairs:
            return []
        n = len(self.shards)
        by_shard: Dict[int, List[int]] = {}
        for i, (env, _) in enumerate(pairs):
            by_shard.setdefault(shard_of(env.task_id, n), []).append(i)
        chosen: List[Optional[str]] = [None] * len(pairs)
        for idx, indices in by_shard.items():
            self.metrics.counter(
                "forwarder.shard_tasks", {"shard": str(idx)}
            ).inc(len(indices))
            sub = self.shards[idx].submit_many(
                [pairs[i] for i in indices], endpoint_id=endpoint_id
            )
            for i, eid in zip(indices, sub):
                chosen[i] = eid
        return chosen

    def shard(self, n: int, requirements=()) -> List[Tuple[str, int]]:
        """Capacity-proportional fan-out split (endpoint view is identical
        across shards, so shard 0 answers for all)."""
        return self.shards[0].shard(n, requirements=requirements)

    def pump_once(self, force: bool = False) -> int:
        return sum(fwd.pump_once(force=force) for fwd in self.shards)

    def check_endpoints(self) -> List[str]:
        dead: List[str] = []
        for fwd in self.shards:
            for eid in fwd.check_endpoints():
                if eid not in dead:
                    dead.append(eid)
        return dead

    def check_speculation(self) -> int:
        return sum(fwd.check_speculation() for fwd in self.shards)

    def shutdown(self) -> None:
        for fwd in self.shards:
            fwd.shutdown()

    def stats(self) -> dict:
        per_shard = [fwd.stats() for fwd in self.shards]
        endpoints: Dict[str, dict] = {}
        for s in per_shard:
            for eid, ep in s["endpoints"].items():
                agg = endpoints.setdefault(eid, {
                    "routed": 0, "completed": 0, "outstanding": 0,
                    "pending": 0, "dead": ep["dead"], "capacity": ep["capacity"],
                })
                for k in ("routed", "completed", "outstanding", "pending"):
                    agg[k] += ep[k]
                agg["dead"] = agg["dead"] and ep["dead"]
        return {
            "policy": self.policy,
            "n_shards": len(self.shards),
            "fairness": self.fairness is not None,
            "failovers": self.failovers,
            "orphaned": self.orphaned,
            "sessions": len(self.sessions),
            "speculation": self.speculation,
            "backups_launched": self.backups_launched,
            "batches_delivered": sum(s["batches_delivered"] for s in per_shard),
            "tasks_delivered": sum(s["tasks_delivered"] for s in per_shard),
            "endpoints": endpoints,
            "shards": per_shard,
        }
