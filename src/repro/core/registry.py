"""Function registry.

funcX requires functions to be *registered* before invocation; the registry
assigns each function a content-derived id so that (a) memoization can key on
the function body (paper §5.5: "hashing the function body and input document")
and (b) re-registering identical code is idempotent.
"""
from __future__ import annotations

import hashlib
import inspect
import textwrap
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Optional, Union

from .containers import ResourceSpec


def hash_function(fn: Callable, static: Any = None) -> str:
    """Content hash of a function body (+ optional static configuration).

    Uses the dedented source when available (matches funcX's body-hash
    semantics); falls back to the compiled code object for builtins/lambdas
    defined in exotic places. Closure cell values are folded in so two
    closures over different constants hash differently.
    """
    h = hashlib.sha256()
    try:
        src = textwrap.dedent(inspect.getsource(fn))
        h.update(src.encode())
    except (OSError, TypeError):
        code = getattr(fn, "__code__", None)
        if code is not None:
            h.update(code.co_code)
            h.update(repr(code.co_consts).encode())
        else:
            h.update(repr(fn).encode())
    closure = getattr(fn, "__closure__", None)
    if closure:
        for cell in closure:
            try:
                h.update(repr(cell.cell_contents).encode())
            except ValueError:  # empty cell
                pass
    if static is not None:
        h.update(repr(static).encode())
    return h.hexdigest()


@dataclass
class RegisteredFunction:
    function_id: str
    fn: Callable
    name: str
    description: str = ""
    owner: str = "anonymous"
    public: bool = False
    # what this function requires from the fabric: capabilities the executing
    # container pool must provide + the container variant it prefers
    requirements: ResourceSpec = field(default_factory=ResourceSpec)
    # serving hints
    batchable: bool = False       # payloads may be stacked on a leading axis
    deterministic: bool = True    # eligible for memoization
    metadata: dict = field(default_factory=dict)


class FunctionRegistry:
    """Thread-safe registry mapping function_id -> RegisteredFunction."""

    def __init__(self):
        self._lock = threading.Lock()
        self._functions: dict[str, RegisteredFunction] = {}

    def register(
        self,
        fn: Callable,
        name: Optional[str] = None,
        description: str = "",
        owner: str = "anonymous",
        public: bool = False,
        static: Any = None,
        requirements: Union[ResourceSpec, Iterable[str], None] = None,
        batchable: bool = False,
        deterministic: bool = True,
        **metadata: Any,
    ) -> str:
        if requirements is None:
            requirements = ResourceSpec()
        elif not isinstance(requirements, ResourceSpec):
            # a bare capability iterable is the common shorthand
            requirements = ResourceSpec(capabilities=frozenset(requirements))
        fid = hash_function(fn, static=static)
        with self._lock:
            if fid not in self._functions:
                self._functions[fid] = RegisteredFunction(
                    function_id=fid,
                    fn=fn,
                    name=name or getattr(fn, "__name__", "anonymous"),
                    description=description,
                    owner=owner,
                    public=public,
                    requirements=requirements,
                    batchable=batchable,
                    deterministic=deterministic,
                    metadata=dict(metadata),
                )
        return fid

    def get(self, function_id: str) -> RegisteredFunction:
        with self._lock:
            try:
                return self._functions[function_id]
            except KeyError:
                raise KeyError(f"unknown function_id {function_id!r}") from None

    def __contains__(self, function_id: str) -> bool:
        with self._lock:
            return function_id in self._functions

    def list(self) -> list[RegisteredFunction]:
        with self._lock:
            return list(self._functions.values())

    def authorized(self, function_id: str, identity: str) -> bool:
        """Invocation permission: the owner themselves, or anyone when the
        owner explicitly opted in with ``public=True``. Ownership is a strict
        identity comparison — an anonymous-owned function is only open to the
        anonymous identity (the no-authority deployment), never a wildcard
        that makes every unowned function world-executable."""
        rf = self.get(function_id)
        return rf.public or rf.owner == identity
