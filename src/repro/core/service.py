"""The funcX service (paper §5.1): registry + routing + memoization + auth.

REST-shaped API surface:
    register_function(fn, ...)          -> function_id
    register_endpoint(endpoint, ...)    -> endpoint_id
    run(function_id, payload, ...)      -> TaskFuture (async) or result (sync)
    batch_run(function_id, payloads)    -> [TaskFuture]  (user-driven batching)
    status(task) / result(task)

Invocation is federated: tasks flow service -> Forwarder -> endpoint, so a
request executes "without regard for the physical resource location". Passing
an explicit ``endpoint_id`` pins a task but still travels through the
Forwarder so liveness tracking and failover apply. ``map()`` fan-outs are
sharded across endpoints proportional to advertised capacity.

All invocation paths stamp the Fig.-5 timestamp trail. Memoization (§5.5) is
service-side: hits complete the future immediately without touching an
endpoint.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from . import auth as auth_mod
from . import serializer
from .auth import Token, TokenAuthority
from .batching import stack_payloads, unstack_results
from .containers import ResourceSpec
from .datastore import (
    DEFAULT_SPILL_THRESHOLD,
    DataRef,
    ObjectStore,
    resolve_payload,
    scan_refs,
    spill_payload,
)
from .endpoint import Endpoint
from .fairness import FairnessPolicy
from .forwarder import Forwarder, ShardedForwarder
from .futures import TaskEnvelope, TaskFuture, TaskState, new_task_id
from .journal import Journal, ResumeReport
from .memoization import MemoCache
from .metrics import MetricsRegistry
from .registry import FunctionRegistry
from .worker import TaskResult


@dataclass
class Invocation:
    """One invocation spec for :meth:`FunctionService.run_many`.

    Unlike ``batch_run`` (one function, many payloads), a sequence of
    Invocations may name different functions and still travel the fabric as
    one batch — the submission shape of a workflow's ready set, where sibling
    DAG nodes run different functions but should ride one TaskBatch frame.
    """

    function_id: str
    payload: Any
    endpoint_id: Optional[str] = None
    container: str = "default"
    # Per-invocation capability override; None inherits the registered
    # function's ResourceSpec capabilities. A task travels the fabric only
    # through endpoints/pools providing every listed capability.
    requirements: Optional[Sequence[str]] = None
    memoize: bool = False
    max_retries: int = 2
    affinity_hint: Optional[str] = None
    # Serving-session stickiness: tasks sharing a session_id route to one
    # endpoint while it lives (the Forwarder's SessionRouter owns the
    # binding); see docs/serving.md.
    session_id: Optional[str] = None
    # Durability ownership: who re-drives this task after a fabric restart.
    # None = a standalone client task (``FunctionService.resume`` re-submits
    # it from the journal); a workflow run_id = the workflow engine owns it
    # (``Workflow.resume`` re-executes the node, so service-level resume must
    # not double-submit the same work).
    owner: Optional[str] = None


def _scan_futures(payload: Any, found: Optional[List[TaskFuture]] = None) -> List[TaskFuture]:
    """Collect TaskFuture leaves nested anywhere in a payload pytree."""
    if found is None:
        found = []
    if isinstance(payload, TaskFuture):
        found.append(payload)
    elif isinstance(payload, dict):
        for v in payload.values():
            _scan_futures(v, found)
    elif isinstance(payload, (list, tuple)):
        for v in payload:
            _scan_futures(v, found)
    return found


def _resolve_futures(payload: Any) -> Any:
    """Substitute each (completed) TaskFuture leaf with its result."""
    if isinstance(payload, TaskFuture):
        return payload.result(0)
    if isinstance(payload, dict):
        return {k: _resolve_futures(v) for k, v in payload.items()}
    if isinstance(payload, (list, tuple)):
        out = [_resolve_futures(v) for v in payload]
        return tuple(out) if isinstance(payload, tuple) else out
    return payload


class FunctionService:
    def __init__(
        self,
        authority: Optional[TokenAuthority] = None,
        memo_entries: int = 4096,
        policy: str = "least_outstanding",
        forwarder: Optional[Forwarder] = None,
        metrics: Optional[MetricsRegistry] = None,
        journal: Optional[Journal] = None,
        journal_dir: Optional[str] = None,
        datastore: Optional[ObjectStore] = None,
        spill_threshold: int = DEFAULT_SPILL_THRESHOLD,
        n_shards: int = 1,
        fairness: Optional[FairnessPolicy] = None,
    ):
        self.registry = FunctionRegistry()
        self.memo = MemoCache(max_entries=memo_entries)
        self.authority = authority
        # Fairness quotas/weights declared on the authority's tenant profiles
        # apply fabric-wide (explicit policy entries still win).
        if fairness is not None and authority is not None:
            fairness.bind_profiles(authority)
        # One MetricsRegistry per fabric: the forwarder and every registered
        # endpoint (and its executors/warm pools) bind to it, so
        # ``self.metrics.snapshot()`` is the whole-fabric telemetry surface.
        if forwarder is not None:
            self.forwarder = forwarder
            self.metrics = metrics if metrics is not None else forwarder.metrics
            # unify unconditionally: record gauges keep their values, and any
            # endpoint registered before adoption binds to the fabric
            # registry — telemetry must never split across registries
            forwarder.rebind_metrics(self.metrics)
            # a pre-built fair forwarder still learns the authority's profiles
            if authority is not None and getattr(forwarder, "fairness", None) is not None:
                forwarder.fairness.bind_profiles(authority)
        else:
            self.metrics = metrics if metrics is not None else MetricsRegistry()
            if n_shards > 1:
                # million-task scale: hash-partitioned forwarder shards, each
                # with its own lock/pump/watchdog (see ShardedForwarder)
                self.forwarder = ShardedForwarder(
                    n_shards=n_shards, policy=policy, metrics=self.metrics,
                    fairness=fairness,
                )
            else:
                self.forwarder = Forwarder(
                    policy=policy, metrics=self.metrics, fairness=fairness
                )
        # Durability: with a journal attached, every task and workflow-run
        # lifecycle transition is written ahead, and resume() rehydrates
        # incomplete work after a restart (see docs/durability.md).
        if journal is None and journal_dir is not None:
            journal = Journal(journal_dir, metrics=self.metrics)
        self.journal = journal
        if journal is not None and self.forwarder.journal is None:
            self.forwarder.journal = journal
        # Data fabric: with a store attached, payload leaves of at least
        # `spill_threshold` packed bytes travel as DataRefs (resolved at the
        # endpoint, near the workers), and workers spill oversized results
        # back into the same store. Without a store refs in user payloads
        # still route and resolve; nothing auto-spills.
        self.datastore = datastore
        self.spill_threshold = spill_threshold
        if datastore is not None:
            datastore.bind_metrics(self.metrics)

    @property
    def endpoints(self) -> Dict[str, Endpoint]:
        """Registered endpoints, derived from the forwarder's registry (the
        single source of truth, so fabric-level deregistration cannot desync)."""
        return self.forwarder.endpoints()

    # -- auth ------------------------------------------------------------
    def _identity(self, token: Optional[Token], scope: str) -> str:
        if self.authority is None:
            return "anonymous"
        return self.authority.verify(token, scope)

    # -- registration ------------------------------------------------------
    def register_function(
        self,
        fn: Callable,
        name: Optional[str] = None,
        description: str = "",
        public: bool = False,
        requirements: "ResourceSpec | Sequence[str] | None" = None,
        token: Optional[Token] = None,
        **metadata: Any,
    ) -> str:
        owner = self._identity(token, auth_mod.SCOPE_REGISTER_FUNCTION)
        return self.registry.register(
            fn, name=name, description=description, owner=owner, public=public,
            requirements=requirements, **metadata
        )

    def register_endpoint(
        self,
        endpoint: Endpoint,
        token: Optional[Token] = None,
    ) -> str:
        self._identity(token, auth_mod.SCOPE_REGISTER_ENDPOINT)
        endpoint.result_hook = self._on_result
        endpoint.memo_probe = self._memo_probe
        if hasattr(endpoint, "bind_metrics"):
            endpoint.bind_metrics(self.metrics)
        return self.forwarder.register(endpoint)

    def make_endpoint(self, name: str, token: Optional[Token] = None,
                      **kwargs: Any) -> Endpoint:
        """Convenience: construct an Endpoint bound to this service's registry."""
        kwargs.setdefault("metrics", self.metrics)
        ep = Endpoint(name=name, registry=self.registry, result_hook=self._on_result, **kwargs)
        self.register_endpoint(ep, token=token)
        return ep

    # -- invocation ---------------------------------------------------------
    # ``_submit`` is THE submission path: run(), batch_run(), run_many(),
    # map(), and the workflow engine all collapse onto it. The public names
    # are thin keyword-compatible shims.
    def _submit(
        self,
        invocations: Sequence[Invocation],
        token: Optional[Token] = None,
    ) -> List[TaskFuture]:
        """Submit a heterogeneous batch: each :class:`Invocation` may name a
        different function, yet everything routable now travels the Forwarder
        as ONE batch per endpoint pin. Auth and registry lookups are paid once
        per distinct function, not once per task.

        Dependency-aware submission ("futures as inputs"): a payload may embed
        :class:`TaskFuture` leaves anywhere in its pytree. Such tasks are held
        back until every input future resolves, then submitted with the input
        results substituted in place — an upstream failure fails the dependent
        task without it ever reaching an endpoint.
        """
        t_submit = time.monotonic()
        identity = self._identity(token, auth_mod.SCOPE_INVOKE)
        fns = {}
        for inv in invocations:  # auth/registry paid once per distinct function
            if inv.function_id not in fns:
                rf = self.registry.get(inv.function_id)
                if not self.registry.authorized(inv.function_id, identity):
                    raise auth_mod.AuthError(f"{identity} may not invoke {rf.name}")
                fns[inv.function_id] = rf
        t_service_in = time.monotonic()
        self.metrics.counter("service.tasks_submitted").inc(len(invocations))

        futures: List[TaskFuture] = []
        groups: Dict[Optional[str], List[Tuple[TaskEnvelope, TaskFuture]]] = {}
        for inv in invocations:
            rf = fns[inv.function_id]
            wire = rf.metadata.get("pass_through", False)
            memoizable = inv.memoize and rf.deterministic and not wire
            future = TaskFuture(new_task_id())
            future.timestamps.client_submit = t_submit
            future.timestamps.service_in = t_service_in
            future.add_done_callback(self._observe_completion)
            futures.append(future)

            inputs = [] if wire else _scan_futures(inv.payload)
            if inputs:
                self._submit_deferred(
                    inv, rf, future, inputs, memoizable, wire, identity
                )
                continue
            env = self._build_envelope(
                inv, rf, future, inv.payload, memoizable, wire, identity
            )
            if env is not None:  # None = served from the memo cache
                groups.setdefault(inv.endpoint_id, []).append((env, future))
        for endpoint_id, pairs in groups.items():
            self.forwarder.submit_many(pairs, endpoint_id=endpoint_id)
        return futures

    def run_many(
        self,
        invocations: Sequence[Invocation],
        token: Optional[Token] = None,
    ) -> List[TaskFuture]:
        """Heterogeneous batch submission (back-compat name for the unified
        :meth:`_submit` path)."""
        return self._submit(invocations, token=token)

    def _build_envelope(
        self,
        inv: Invocation,
        rf,
        future: TaskFuture,
        payload: Any,
        memoizable: bool,
        wire: bool,
        identity: Optional[str] = None,
    ) -> Optional[TaskEnvelope]:
        """Memo-check `payload` and wrap it for the wire. Returns None when the
        memo cache completed the future without needing an endpoint."""
        digest = None
        if memoizable:
            digest = serializer.payload_hash(payload)
            hit, value = self.memo.get(inv.function_id, digest)
            if hit:
                self.metrics.counter("service.memo_hits").inc()
                future.set_result(value, state=TaskState.MEMOIZED)
                return None
        # capability resolution: per-invocation override, else the function's
        # registered ResourceSpec; the default container name defers to the
        # function's preferred container variant
        if inv.requirements is not None:
            requirements = tuple(sorted(inv.requirements))
        else:
            requirements = tuple(sorted(rf.requirements.capabilities))
        container = inv.container
        if container == "default" and rf.requirements.preferred_container:
            container = rf.requirements.preferred_container
        # Data fabric: spill (or just scan for) DataRef leaves AFTER the memo
        # digest — the key is computed over the original payload, and the
        # location-free hash view keeps it identical either way.
        refs: list = []
        if not wire:
            if self.datastore is not None:
                payload, refs = spill_payload(
                    payload, self.datastore, self.spill_threshold,
                    metrics=self.metrics,
                )
            else:
                refs = scan_refs(payload)
        env = TaskEnvelope(
            task_id=future.task_id,
            function_id=inv.function_id,
            payload=payload if wire else serializer.packb(payload),
            container=container,
            requirements=requirements,
            memoize=digest is not None,
            max_retries=inv.max_retries,
            affinity_hint=inv.affinity_hint,
            session_id=inv.session_id,
            data_refs=tuple((r.key, r.size) for r in refs),
            spill_store=(
                self.datastore.store_id if self.datastore is not None else None
            ),
            spill_threshold=(
                self.spill_threshold if self.datastore is not None else None
            ),
            tenant=identity,
        )
        env.timestamps.client_submit = future.timestamps.client_submit
        env.timestamps.service_in = future.timestamps.service_in
        if digest is not None:
            env.__dict__["_memo_digest"] = digest
        if self.journal is not None:
            # write-ahead: the submitted record lands before the task can
            # reach any endpoint, so a crash after this point is resumable
            self.journal.append(
                "task", "submitted",
                task_id=env.task_id,
                function_id=env.function_id,
                payload=env.payload if isinstance(env.payload, bytes) else None,
                container=env.container,
                requirements=list(env.requirements),
                max_retries=env.max_retries,
                owner=inv.owner,
            )
        return env

    def _submit_deferred(
        self,
        inv: Invocation,
        rf,
        future: TaskFuture,
        inputs: List[TaskFuture],
        memoizable: bool,
        wire: bool,
        identity: Optional[str] = None,
    ) -> None:
        """Hold `inv` until every input future resolves, then substitute the
        results into the payload and submit. First input failure wins and
        fails the dependent future immediately."""
        state = {"remaining": len(inputs)}
        lock = threading.Lock()

        def _on_input(done: TaskFuture) -> None:
            exc = done.exception(0)
            if exc is not None:
                future.set_exception(exc)
                return
            with lock:
                state["remaining"] -= 1
                if state["remaining"]:
                    return
            if future.done():  # a sibling input already failed us
                return
            try:
                payload = _resolve_futures(inv.payload)
                env = self._build_envelope(
                    inv, rf, future, payload, memoizable, wire, identity
                )
                if env is not None:
                    self.forwarder.submit(env, future, endpoint_id=inv.endpoint_id)
            except BaseException as exc:  # noqa: BLE001 - must reach the future
                future.set_exception(exc)

        for f in inputs:
            f.add_done_callback(_on_input)

    def _submit_tasks(
        self,
        function_id: str,
        payloads: Sequence[Any],
        endpoint_id: Optional[str] = None,
        container: str = "default",
        requirements: Optional[Sequence[str]] = None,
        memoize: bool = False,
        max_retries: int = 2,
        token: Optional[Token] = None,
        session_id: Optional[str] = None,
    ) -> List[TaskFuture]:
        """Homogeneous batch: one function, many payloads, submitted to the
        Forwarder as ONE batch (a single ``run()`` is simply a batch of one)."""
        return self._submit(
            [
                Invocation(
                    function_id=function_id,
                    payload=payload,
                    endpoint_id=endpoint_id,
                    container=container,
                    requirements=requirements,
                    memoize=memoize,
                    max_retries=max_retries,
                    session_id=session_id,
                )
                for payload in payloads
            ],
            token=token,
        )

    def run(
        self,
        function_id: str,
        payload: Any,
        endpoint_id: Optional[str] = None,
        container: str = "default",
        requirements: Optional[Sequence[str]] = None,
        memoize: bool = False,
        sync: bool = False,
        max_retries: int = 2,
        token: Optional[Token] = None,
        timeout: Optional[float] = None,
        session_id: Optional[str] = None,
    ) -> Any:
        future = self._submit_tasks(
            function_id,
            [payload],
            endpoint_id,
            container=container,
            requirements=requirements,
            memoize=memoize,
            max_retries=max_retries,
            token=token,
            session_id=session_id,
        )[0]
        return future.result(timeout) if sync else future

    def batch_run(
        self,
        function_id: str,
        payloads: Sequence[Any],
        endpoint_id: Optional[str] = None,
        user_batched: bool = False,
        **kwargs: Any,
    ) -> List[TaskFuture]:
        """N invocations. With user_batched=True the payloads are stacked into
        ONE invocation (paper §5.5 'user-driven batching', Fig. 8) and the
        stacked result is split back into N per-request futures. Otherwise the
        N tasks travel as one TaskBatch through the Forwarder, amortizing
        auth, registry lookups, and routing locks across the batch."""
        if not user_batched:
            sync = kwargs.pop("sync", False)
            timeout = kwargs.pop("timeout", None)
            futures = self._submit_tasks(function_id, list(payloads), endpoint_id, **kwargs)
            if sync:
                return [f.result(timeout) for f in futures]
            return futures
        stacked = stack_payloads(list(payloads))
        inner = self.run(function_id, stacked, endpoint_id, **kwargs)
        outs = [TaskFuture(f"{inner.task_id}/{i}") for i in range(len(payloads))]

        def _split(done: TaskFuture) -> None:
            try:
                results = unstack_results(done.result(), len(outs))
                for f, r in zip(outs, results):
                    f.timestamps = done.timestamps
                    f.set_result(r)
            except BaseException as exc:  # noqa: BLE001
                for f in outs:
                    f.set_exception(exc)

        inner.add_done_callback(_split)
        return outs

    def map(self, function_id: str, payloads: Sequence[Any], endpoint_id: Optional[str] = None,
            timeout: Optional[float] = 120.0, **kwargs: Any) -> List[Any]:
        """Fan out N invocations and gather results in order. With several live
        endpoints and no pin, the fan-out is sharded across endpoints
        proportional to their advertised capacity."""
        payloads = list(payloads)
        if (
            endpoint_id is None
            and not kwargs.get("user_batched")
            and self.forwarder.live_count() > 1
        ):
            kwargs.pop("user_batched", None)  # falsy here; _submit_tasks doesn't take it
            req = kwargs.get("requirements")
            if req is None:
                req = tuple(sorted(self.registry.get(function_id).requirements.capabilities))
            futs: List[TaskFuture] = []
            start = 0
            for eid, count in self.forwarder.shard(len(payloads), requirements=req):
                if count:  # each shard travels as one pinned batch
                    futs.extend(
                        self._submit_tasks(
                            function_id, payloads[start : start + count],
                            endpoint_id=eid, **kwargs,
                        )
                    )
                start += count
            if start < len(payloads):  # defensive: shard() should cover all
                futs.extend(self._submit_tasks(function_id, payloads[start:], **kwargs))
            return [f.result(timeout) for f in futs]
        futs = self.batch_run(function_id, payloads, endpoint_id, **kwargs)
        return [f.result(timeout) for f in futs]

    # -- durability ------------------------------------------------------------
    def resume(
        self,
        journal_dir: Optional[str] = None,
        workflows: Sequence[Any] = (),
        token: Optional[Token] = None,
    ) -> ResumeReport:
        """Rehydrate incomplete work from a journal after a fabric restart.

        Re-executes ONLY work without a committed terminal record: standalone
        tasks are re-submitted through the Forwarder under their original
        task ids (so the eventual terminal record matches the journal entry),
        and incomplete workflow runs are handed to their matching definition
        in `workflows` (``Workflow.resume`` re-runs only unfinished nodes).
        Every already-terminal task id is primed into the Forwarder's
        :class:`~repro.core.journal.ResultStore` first, so a replayed late
        delivery for committed work dedupes instead of resolving twice.
        """
        if journal_dir is not None:
            journal = Journal(journal_dir, metrics=self.metrics)
            self.journal = journal
            self.forwarder.journal = journal
        if self.journal is None:
            raise ValueError(
                "resume() needs a journal: pass journal_dir or construct "
                "the service with one"
            )
        self._identity(token, auth_mod.SCOPE_INVOKE)
        st = self.journal.state()
        report = ResumeReport(state=st)
        for entry in st.tasks.values():
            if entry.terminal:  # exactly-once: committed results never re-resolve
                self.forwarder.results.prime(entry.task_id)
        by_name: Dict[str, Any] = {}
        for wf in workflows:
            by_name.setdefault(wf.name, wf)
        for run_entry in st.incomplete_runs():
            wf = by_name.get(run_entry.workflow)
            if wf is None:
                report.skipped.append(
                    (run_entry.run_id,
                     f"no definition for workflow {run_entry.workflow!r}")
                )
                continue
            report.runs[run_entry.run_id] = wf.resume(
                self, run_entry, token=token
            )
            self.metrics.counter("journal.resumed_runs").inc()
        pairs: List[Tuple[TaskEnvelope, TaskFuture]] = []
        for entry in st.incomplete_tasks():
            if entry.owner is not None:
                continue  # the owning workflow run re-executes this node
            if not entry.resumable:
                report.skipped.append((entry.task_id, "payload not journaled"))
                continue
            try:
                self.registry.get(entry.function_id)
            except KeyError:
                report.skipped.append(
                    (entry.task_id,
                     f"function {entry.function_id!r} not registered")
                )
                continue
            now = time.monotonic()
            future = TaskFuture(entry.task_id)  # original id: stable identity
            future.timestamps.client_submit = now
            future.timestamps.service_in = now
            future.add_done_callback(self._observe_completion)
            env = TaskEnvelope(
                task_id=entry.task_id,
                function_id=entry.function_id,
                payload=entry.payload,
                container=entry.container,
                requirements=entry.requirements,
                max_retries=entry.max_retries,
                spill_store=(
                    self.datastore.store_id
                    if self.datastore is not None else None
                ),
                spill_threshold=(
                    self.spill_threshold
                    if self.datastore is not None else None
                ),
            )
            env.timestamps.client_submit = now
            env.timestamps.service_in = now
            # re-discover DataRef leaves: the journal holds the small
            # ref-bearing bytes, and endpoints resolve from a ref's own
            # locations (fs:// stores re-attach by path after a restart)
            try:
                # scan-only decode: never handed to user code → zero-copy
                refs = scan_refs(serializer.unpackb(entry.payload, writable=False))
            except Exception:
                refs = []
            env.data_refs = tuple((r.key, r.size) for r in refs)
            self.journal.append(  # idempotent under the fold
                "task", "submitted",
                task_id=entry.task_id, function_id=entry.function_id,
                payload=entry.payload, container=entry.container,
                requirements=list(entry.requirements),
                max_retries=entry.max_retries, owner=None,
            )
            pairs.append((env, future))
            report.futures[entry.task_id] = future
            self.metrics.counter("journal.resumed_tasks").inc()
        if pairs:
            self.forwarder.submit_many(pairs)
        return report

    # -- status/result (REST-shaped) ------------------------------------------
    @staticmethod
    def status(future: TaskFuture) -> str:
        return future.state.value

    @staticmethod
    def result(future: TaskFuture, timeout: Optional[float] = None) -> Any:
        return future.result(timeout)

    # -- data fabric client surface --------------------------------------------
    def put_data(self, value: Any) -> DataRef:
        """Store `value` once and get a :class:`DataRef` usable as a payload
        leaf in any number of invocations — the N-tasks-share-one-dataset
        pattern (each endpoint fetches the blob once into its locality
        cache; the Forwarder never carries it inline)."""
        if self.datastore is None:
            raise ValueError("put_data() needs a datastore attached to the service")
        blob = serializer.packb(value)
        key = self.datastore.put(blob)
        return DataRef(key=key, size=len(blob),
                       locations=(self.datastore.store_id,))

    def fetch(self, value: Any, timeout: Optional[float] = None) -> Any:
        """Materialize any DataRef leaves in `value` (a result, a payload, or
        a TaskFuture whose result may carry spilled leaves)."""
        if isinstance(value, TaskFuture):
            value = value.result(timeout)
        return resolve_payload(value, metrics=self.metrics)

    # -- hooks -----------------------------------------------------------------
    def _observe_completion(self, future: TaskFuture) -> None:
        """Done-callback on every future built by this service: end-to-end
        success/failure counts and the client-observed latency histogram.
        With a journal attached this is also the commitment point — the
        terminal record lands exactly once per task (the future resolves at
        most once, so this callback fires at most once)."""
        if self.journal is not None:
            exc = future.exception(0)
            if exc is None:
                try:
                    value = serializer.packb(future.result(0))
                except Exception:
                    value = None  # unserializable result: committed in-memory only
                self.journal.append(
                    "task", "completed", task_id=future.task_id, value=value
                )
            else:
                self.journal.append(
                    "task", "failed", task_id=future.task_id, error=repr(exc)
                )
        if future.exception(0) is None:
            self.metrics.counter("service.tasks_completed").inc()
            ts = future.timestamps
            if ts.result_ready and ts.client_submit:
                self.metrics.histogram("service.e2e_latency_s").observe(
                    ts.result_ready - ts.client_submit
                )
        else:
            self.metrics.counter("service.tasks_failed").inc()

    def _on_result(self, env: TaskEnvelope, res: TaskResult) -> None:
        digest = env.__dict__.get("_memo_digest")
        if env.memoize and digest is not None and res.error is None:
            self.memo.put(env.function_id, digest, res.value)

    def _memo_probe(self, env: TaskEnvelope):
        """Queue-time memo lookup for the endpoint's dispatch loop."""
        digest = env.__dict__.get("_memo_digest")
        if digest is None:
            return False, None
        return self.memo.get(env.function_id, digest)

    # -- lifecycle ---------------------------------------------------------------
    def shutdown(self) -> None:
        self.forwarder.shutdown()
        for eid, ep in self.endpoints.items():
            ep.shutdown()
            self.forwarder.deregister(eid)

    def stats(self) -> dict:
        return {
            "functions": len(self.registry.list()),
            "endpoints": {eid: ep.stats() for eid, ep in self.endpoints.items()},
            "forwarder": self.forwarder.stats(),
            "memo": self.memo.stats(),
            "metrics": self.metrics.snapshot(),
        }
