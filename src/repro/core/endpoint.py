"""Endpoint: manager + executor pool (paper §5.3–5.4).

The Manager "queues and forwards function execution requests and results,
interacts with resource schedulers, and batches and load balances requests";
it detects failures via heartbeats + a watchdog, re-executes lost tasks,
suspends failed executors, and scales resources through the provider.

Beyond-paper: speculative re-execution of stragglers (p95 × multiplier,
first-result-wins) and warm-affinity scheduling.
"""
from __future__ import annotations

import queue
import threading
import time
import uuid
from collections import deque
from typing import Any, Callable, Dict, List, Optional

from .autoscaler import Autoscaler, ScalingObservation, ScalingPolicy
from .containers import CapabilityError, ContainerSpec, default_container_spec
from . import serializer
from .datastore import InMemoryStore, ObjectStore, prefetch_refs, scan_refs
from .executor import Executor
from .futures import TaskEnvelope, TaskFuture, TaskState
from .heartbeat import HeartbeatMonitor, LatencyTracker
from .interchange import ResultBatch, TaskBatch
from .metrics import MetricsRegistry
from .provider import LocalThreadProvider, Provider, ProviderSpec
from .registry import FunctionRegistry
from .scheduler import Scheduler
from .worker import SiteRuntime, TaskResult


class Endpoint:
    def __init__(
        self,
        name: str,
        registry: FunctionRegistry,
        n_executors: int = 1,
        workers_per_executor: int = 4,
        prefetch: int = 0,
        policy: str = "random",
        provider: Optional[Provider] = None,
        heartbeat_interval_s: float = 0.25,
        heartbeat_threshold: float = 2.0,
        elastic: bool = False,
        max_executors: int = 8,
        speculation: bool = False,
        speculation_multiplier: float = 3.0,
        warm_ttl_s: float = 300.0,
        containers: Optional[List[ContainerSpec]] = None,
        container_keep_alive_s: Optional[float] = None,
        tick_s: float = 0.001,
        dispatch_interval_s: float = 0.0,
        result_hook: Optional[Callable[[TaskEnvelope, TaskResult], None]] = None,
        memo_probe: Optional[Callable[[TaskEnvelope], tuple]] = None,
        metrics: Optional[MetricsRegistry] = None,
        scaling_policy: "str | ScalingPolicy" = "queue_depth",
        scale_cooldown_s: float = 30.0,
        scale_step_fraction: float = 0.5,
        target_tasks_per_worker: float = 2.0,
        latency_slo_s: float = 1.0,
        data_cache: Optional[ObjectStore] = None,
    ):
        self.endpoint_id = f"ep-{uuid.uuid4().hex[:8]}"
        self.name = name
        self.registry = registry
        self.workers_per_executor = workers_per_executor
        self.prefetch = prefetch
        self.scheduler = Scheduler(policy)
        self.monitor = HeartbeatMonitor(heartbeat_interval_s, heartbeat_threshold)
        self.heartbeat_interval_s = heartbeat_interval_s
        self.elastic = elastic
        self.speculation = speculation
        self.speculation_multiplier = speculation_multiplier
        self.warm_ttl_s = warm_ttl_s
        # container types every executor on this endpoint hosts; default is
        # the homogeneous seed shape — one fixed-size cpu pool per executor
        self.container_specs: List[ContainerSpec] = (
            list(containers)
            if containers
            else [default_container_spec(workers_per_executor)]
        )
        self.container_keep_alive_s = container_keep_alive_s
        # per-block worker ceiling across hosted pools: what one executor
        # grows to on demand (== workers_per_executor for the default spec)
        self._block_workers = sum(s.max_workers for s in self.container_specs)
        self.tick_s = tick_s
        # simulated manager<->executor RTT: dispatch rounds happen at most
        # this often (0 = in-process, dispatch on every loop iteration)
        self.dispatch_interval_s = dispatch_interval_s
        self.result_hook = result_hook
        self.memo_probe = memo_probe
        self.tracker = LatencyTracker()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # Data fabric locality cache: DataRef payload leaves materialize here
        # at dispatch time, so a dataset shared by N tasks crosses the
        # store->endpoint boundary once. Unregistered (refs never point AT a
        # cache) and endpoint-private.
        self.data_cache: ObjectStore = (
            data_cache
            if data_cache is not None
            else InMemoryStore(
                store_id=f"cache://{self.endpoint_id}", register=False
            )
        )
        # Decoded-value companion to the blob cache: the msgpack decode of a
        # shared blob runs once per endpoint, workers hand out fresh copies
        # (see resolve_payload(decoded=...)). Plain dict — worker threads may
        # race to populate a key, which is harmless.
        self.data_decoded: Dict[str, Any] = {}
        # Endpoint-scoped runtime state for site-aware functions (the serving
        # tier's per-endpoint model hosts). The metrics thunk reads late so
        # hosts see the service registry the endpoint rebinds to.
        self.site = SiteRuntime(
            self.endpoint_id, name, metrics_fn=lambda: self.metrics
        )

        self.result_queue: "queue.Queue[TaskResult]" = queue.Queue()
        self._queue: deque[TaskEnvelope] = deque()
        self._qlock = threading.Lock()
        self.futures: Dict[str, TaskFuture] = {}
        self._flock = threading.Lock()
        self.executors: Dict[str, Executor] = {}
        self._block_of: Dict[str, str] = {}  # executor_id -> provider block_id
        self._exlock = threading.Lock()  # guards executors against fabric-thread readers
        self._speculated: set[str] = set()
        self.completed = 0
        self.requeued = 0
        self.lost_executors = 0

        if provider is None:
            provider = LocalThreadProvider(
                ProviderSpec(
                    min_blocks=min(1, n_executors),
                    init_blocks=n_executors,
                    max_blocks=max(max_executors, n_executors),
                    workers_per_block=self._block_workers,
                )
            )
        self.provider = provider
        if isinstance(provider, LocalThreadProvider):
            provider.bind_factory(self._make_executor)
        provider.scale_out(n_executors)
        # All block-count changes flow through the autoscaler: policy ticks at
        # heartbeat cadence when `elastic`, and the watchdog's replacement
        # path (which releases the dead block before requesting a new one, so
        # repeated failures can never exceed ProviderSpec.max_blocks).
        self.autoscaler = Autoscaler(
            provider=self.provider,
            host=self,
            policy=scaling_policy,
            cooldown_s=scale_cooldown_s,
            step_fraction=scale_step_fraction,
            metrics=self.metrics,
            name=self.endpoint_id,  # unique gauge label, matching forwarder tier
            target_tasks_per_worker=target_tasks_per_worker,
            latency_slo_s=latency_slo_s,
        )

        self._alive = True
        self.last_heartbeat = time.monotonic()
        self._manager = threading.Thread(target=self._manager_loop, name=f"{name}/mgr", daemon=True)
        self._manager.start()

    # -- executor factory (provider blocks -> Executors) -----------------
    def _make_executor(self, block_id: str) -> Executor:
        ex = Executor(
            executor_id=f"{self.name}/{block_id}",
            registry=self.registry,
            result_queue=self.result_queue,
            containers=self.container_specs,
            prefetch=self.prefetch,
            warm_ttl_s=self.warm_ttl_s,
            container_keep_alive_s=self.container_keep_alive_s,
            monitor=self.monitor,
            heartbeat_interval_s=self.heartbeat_interval_s,
            metrics=self.metrics,
        )
        with self._exlock:
            self.executors[ex.executor_id] = ex
            self._block_of[ex.executor_id] = block_id
        return ex

    def bind_metrics(self, metrics: MetricsRegistry) -> None:
        """Adopt a fabric-wide registry (called when this endpoint registers
        with a FunctionService) so service-, endpoint-, and executor-tier
        telemetry share one snapshot surface."""
        self.metrics = metrics
        self.autoscaler.metrics = metrics
        for ex in self._executor_list():
            ex.metrics = metrics
            ex.warm_pool.metrics = metrics

    def _executor_list(self) -> List[Executor]:
        with self._exlock:
            return list(self.executors.values())

    # -- submission --------------------------------------------------------
    def submit(self, env: TaskEnvelope, future: TaskFuture) -> None:
        self.submit_batch(TaskBatch(envelopes=[env], futures=[future]))

    def submit_batch(self, batch: TaskBatch) -> None:
        """Accept a TaskBatch frame: one timestamp read, one futures-map
        update, and one queue extension for the whole frame (vs. one of each
        per task on the unbatched path)."""
        now = time.monotonic()
        for env, future in zip(batch.envelopes, batch.futures):
            env.timestamps.endpoint_in = now
            future.timestamps = env.timestamps
        with self._flock:
            for env, future in zip(batch.envelopes, batch.futures):
                self.futures[env.task_id] = future
        for future in batch.futures:
            future.set_state(TaskState.QUEUED)
        with self._qlock:
            self._queue.extend(batch.envelopes)

    def queue_depth(self) -> int:
        with self._qlock:
            return len(self._queue)

    # -- fabric-facing surface (consumed by the Forwarder) -------------------
    def capacity(self) -> int:
        """Advertised worker capacity: what the endpoint tells the fabric it
        can absorb (sum of per-container worker ceilings across accepting
        executors — pools grow to these on demand)."""
        return sum(ex.max_workers for ex in self._executor_list() if ex.accepting())

    def capabilities(self) -> frozenset:
        """Capability set this endpoint advertises to the fabric: the union
        over its hosted container specs. Spec-derived (static), not
        derived from currently-accepting executors: a transient executor
        outage must let requirement-bearing tasks queue through the
        replacement window exactly like requirement-free ones, not fail
        them with a capability error. The Forwarder routes a task here only
        when its requirements are a subset."""
        caps: frozenset = frozenset()
        for spec in self.container_specs:
            caps |= spec.capabilities
        return caps

    def has_warm(self, key) -> bool:
        """Endpoint-tier warm probe: any accepting executor holds a warm
        executable for (function_id, container)."""
        return any(ex.has_warm(key) for ex in self._executor_list() if ex.accepting())

    def has_data(self, key: str) -> bool:
        """Data-locality probe: is this blob already resident in the
        endpoint's cache? The Forwarder's ``eta_aware`` policy charges a
        transfer cost only for ref bytes that are NOT local."""
        return key in self.data_cache

    def is_alive(self, max_heartbeat_age_s: Optional[float] = None) -> bool:
        if not self._alive:
            return False
        if max_heartbeat_age_s is None:
            return True
        return (time.monotonic() - self.last_heartbeat) <= max_heartbeat_age_s

    # -- manager loop -------------------------------------------------------
    def _manager_loop(self) -> None:
        last_watchdog = 0.0
        last_dispatch = 0.0
        while self._alive:
            self.last_heartbeat = time.monotonic()
            # 1) results (block briefly here — it is the latency-critical path)
            try:
                res = self.result_queue.get(timeout=self.tick_s)
                self._handle_frame(res)
                # opportunistically drain the rest
                while True:
                    try:
                        self._handle_frame(self.result_queue.get_nowait())
                    except queue.Empty:
                        break
            except queue.Empty:
                pass
            # 2) watchdog + elasticity + speculation at heartbeat cadence
            now = time.monotonic()
            if now - last_watchdog >= self.heartbeat_interval_s:
                last_watchdog = now
                self._watchdog()
                if self.elastic:
                    self.autoscaler.tick()
                if self.speculation:
                    self._speculate()
                # labeled by endpoint_id, not name: names are user-chosen and
                # same-named endpoints must not merge into one gauge series
                labels = {"endpoint": self.endpoint_id}
                self.metrics.gauge("endpoint.queue_depth", labels).set(
                    self.queue_depth()
                )
                self.metrics.gauge("endpoint.executors_live", labels).set(
                    sum(1 for e in self._executor_list() if e.accepting())
                )
            # 3) dispatch (rate-limited when simulating a WAN RTT)
            now = time.monotonic()
            if now - last_dispatch >= self.dispatch_interval_s:
                last_dispatch = now
                self._dispatch()

    def _handle_frame(self, frame) -> None:
        """Result intake: executors drain their outboxes into ResultBatch
        frames (futures resolved in one lock acquisition per frame); a bare
        TaskResult (legacy producers) is a frame of one."""
        if isinstance(frame, ResultBatch):
            with self._flock:
                futs = [self.futures.get(r.envelope.task_id) for r in frame]
            for res, fut in zip(frame, futs):
                self._handle_result(res, fut)
        else:
            self._handle_result(frame)

    def _handle_result(self, res: TaskResult, fut: Optional[TaskFuture] = None) -> None:
        env = res.envelope
        if fut is None:
            with self._flock:
                fut = self.futures.get(env.task_id)
        if fut is None:
            return
        if res.error is not None:
            if env.retries < env.max_retries:
                self.requeued += 1
                self.metrics.counter("endpoint.tasks_requeued").inc()
                retry = env.clone_for_retry()
                with self._flock:
                    self.futures[retry.task_id] = fut
                with self._qlock:
                    self._queue.appendleft(retry)
            else:
                self._speculated.discard(env.speculative_of or env.task_id)
                if not fut.set_exception(res.exception or RuntimeError(res.error)):
                    # the future already resolved (speculative copy, replayed
                    # frame, cancelled client): exactly-once held, count it
                    self.metrics.counter("journal.duplicate_results").inc()
            return
        # prune straggler bookkeeping once either copy delivers (the set
        # otherwise grows without bound under long-running speculation)
        self._speculated.discard(env.speculative_of or env.task_id)
        won = fut.set_result(res.value)
        if not won:
            # a second completion for an already-resolved future (speculation
            # loser, duplicated/replayed ResultBatch delivery): dedupe to
            # exactly-once resolution and count the duplicate
            self.metrics.counter("journal.duplicate_results").inc()
        if won:
            self.completed += 1
            self.metrics.counter("endpoint.tasks_completed").inc()
            ts = env.timestamps
            if ts.exec_end and ts.endpoint_in:
                self.tracker.record(ts.exec_end - ts.endpoint_in)
            if self.result_hook is not None:
                try:
                    self.result_hook(env, res)
                except Exception:
                    pass

    def _dispatch(self) -> None:
        """Capacity-pulled batch dispatch (paper §5.3/§5.5): each round picks
        an executor for the queue head, then hands it a batch sized to its
        ``free_capacity()`` advertisement (idle workers + prefetch) in one
        pull — instead of re-running the scheduler and re-taking every lock
        once per task."""
        while True:
            with self._qlock:
                if not self._queue:
                    return
                head = self._queue[0]
            executors = self._executor_list()
            ex = self.scheduler.choose(executors, head)
            if ex is None:
                accepting = any(e.accepting() for e in executors)
                if accepting and not self.scheduler.capable(executors, head):
                    # Live pools exist but none can ever run this task: fail
                    # it fast with a capability error instead of letting it
                    # pin the queue head until a watchdog timeout. (The
                    # Forwarder filters on advertised capabilities, so this
                    # is the defense-in-depth for specs changing between
                    # routing and dispatch.) With no accepting executor at
                    # all the task stays queued — executor replacement or
                    # fabric-level failover owns that case.
                    self._fail_incapable(head)
                    continue
                return  # capable executors exist but none has capacity now
            want = max(1, ex.free_capacity_for(head))
            with self._qlock:
                if not self._queue or self._queue[0] is not head:
                    continue
                chunk = [self._queue.popleft()]
                # extend the batch only with tasks this executor can run;
                # the first incompatible task ends the chunk and leads the
                # next dispatch round (which picks its own executor)
                while (
                    len(chunk) < want
                    and self._queue
                    and ex.can_run(self._queue[0])
                ):
                    chunk.append(self._queue.popleft())
            now = time.monotonic()
            dispatch_latency = self.metrics.histogram("endpoint.dispatch_latency_s")
            ready: List[TaskEnvelope] = []
            for env in chunk:
                # queue-time memoization: a result computed while this task
                # waited serves it without dispatch (paper Table 3)
                if env.memoize and self.memo_probe is not None:
                    hit, value = self.memo_probe(env)
                    if hit:
                        with self._flock:
                            fut = self.futures.get(env.task_id)
                        if fut is not None and fut.set_result(value, TaskState.MEMOIZED):
                            self.completed += 1
                        continue
                # data fabric: pull every blob the payload references into
                # the site-local cache (one store read per NEW key — raw
                # bytes only, nothing is unpacked or repacked on this serial
                # loop). Workers then materialize values in parallel from
                # the warmed cache via the env.data_cache handle.
                if env.data_refs and isinstance(env.payload, (bytes, bytearray)):
                    try:
                        payload = serializer.unpackb(env.payload)
                        prefetch_refs(
                            scan_refs(payload), self.data_cache,
                            metrics=self.metrics,
                        )
                        env.payload = payload
                        env.data_cache = self.data_cache
                        env.data_decoded = self.data_decoded
                    except Exception as exc:
                        with self._flock:
                            fut = self.futures.get(env.task_id)
                        if fut is not None:
                            fut.set_exception(
                                KeyError(
                                    f"task {env.task_id}: payload data "
                                    f"unresolvable at {self.name!r}: {exc}"
                                )
                            )
                        continue
                env.timestamps.dispatched = now
                if env.timestamps.endpoint_in:
                    dispatch_latency.observe(now - env.timestamps.endpoint_in)
                env.site = self.site  # where this attempt runs (site-aware fns)
                ready.append(env)
            if not ready:
                continue
            with self._flock:
                futs = [self.futures.get(env.task_id) for env in ready]
            for fut in futs:
                if fut is not None:
                    fut.set_state(TaskState.DISPATCHED)
            ex.submit_batch(ready)

    def _fail_incapable(self, head: TaskEnvelope) -> None:
        """Pop `head` and fail its future with a capability error: no hosted
        container pool provides its required capabilities."""
        with self._qlock:
            if not self._queue or self._queue[0] is not head:
                return
            self._queue.popleft()
        self.metrics.counter("container.capability_misses").inc()
        with self._flock:
            fut = self.futures.pop(head.task_id, None)
        if fut is not None:
            fut.set_exception(
                CapabilityError(
                    f"endpoint {self.name!r} has no container pool providing "
                    f"{sorted(head.requirements)} for task {head.task_id} "
                    f"(advertising {sorted(self.capabilities())})"
                )
            )

    def _watchdog(self) -> None:
        for eid in self.monitor.dead():
            with self._exlock:
                ex = self.executors.get(eid)
            self.monitor.suspend(eid)
            self.lost_executors += 1
            self.metrics.counter("endpoint.executors_lost").inc()
            if ex is None:
                continue
            ex.suspend()
            lost = ex.take_in_flight()
            # also recover tasks sitting in the dead executor's pool queues
            lost.extend(ex.drain_queued())
            for env in lost:
                with self._flock:
                    fut = self.futures.get(env.task_id)
                if fut is None or fut.done():
                    continue
                if env.retries < env.max_retries:
                    fut.set_state(TaskState.LOST)
                    retry = env.clone_for_retry()
                    with self._flock:
                        self.futures[retry.task_id] = fut
                    with self._qlock:
                        self._queue.appendleft(retry)
                    self.requeued += 1
                else:
                    fut.set_exception(RuntimeError(f"task lost with executor {eid}"))
            with self._exlock:
                del self.executors[eid]
                dead_block = self._block_of.pop(eid, None)
            if self.elastic:
                # Replacement flows through the autoscaler: the dead block is
                # released from the provider before a new one is requested, so
                # repeated failures cannot leak blocks past max_blocks.
                self.autoscaler.replace_block(dead_block)
            elif dead_block is not None:
                # Non-elastic: no replacement, but forget the corpse so the
                # provider's block count stays honest. release(), not
                # scale_in(): a false-positive death must leave the executor
                # running so its late result can still resolve the future.
                self.provider.release([dead_block])

    # -- autoscaler host protocol (see core/autoscaler.py) -------------------
    def observe(self) -> ScalingObservation:
        """One heartbeat's load observation for the scaling policy."""
        executors = self._executor_list()
        accepting = [e for e in executors if e.accepting()]
        return ScalingObservation(
            queue_depth=self.queue_depth(),
            # in_flight covers inbox-queued tasks too (submit_batch books a
            # task before the worker pulls it), so count it alone
            outstanding=sum(len(e.in_flight) for e in accepting),
            blocks=len(accepting),
            # ceiling across hosted container specs, not the default-spec
            # knob: with custom containers a block grows past
            # workers_per_executor and the policy must size against that
            workers_per_block=self._block_workers,
            p95_latency_s=self.tracker.p95(),
        )

    def select_idle_block(self) -> Optional[tuple]:
        """A (block_id, executor) scale-in candidate with no queued or
        in-flight work, or None. The autoscaler suspends it, re-verifies
        emptiness, and either releases the block or resumes the executor."""
        with self._exlock:
            items = list(self.executors.items())
            block_of = dict(self._block_of)
        for eid, ex in items:
            if not ex.accepting():
                continue
            if len(ex.in_flight) or ex.queued_tasks():
                continue
            block_id = block_of.get(eid)
            if block_id is not None:
                return block_id, ex
        return None

    def release_block(self, block_id: str) -> None:
        """Drop the executor backing `block_id` from the dispatch tables and
        release the block at the provider (which shuts the executor down)."""
        with self._exlock:
            eid = next(
                (e for e, b in self._block_of.items() if b == block_id), None
            )
            if eid is not None:
                self.executors.pop(eid, None)
                self._block_of.pop(eid, None)
        self.provider.scale_in([block_id])

    def _speculate(self) -> None:
        p95 = self.tracker.p95()
        if p95 is None:
            return
        limit = p95 * self.speculation_multiplier
        for ex in self._executor_list():
            for env in ex.running_longer_than(limit):
                if env.task_id in self._speculated or env.speculative_of:
                    continue
                self._speculated.add(env.task_id)
                # shares the primary's payload object outright — duplicating
                # a straggler must not duplicate its (possibly large) payload
                dup = env.clone_speculative("#spec")
                with self._flock:
                    fut = self.futures.get(env.task_id)
                    if fut is None or fut.done():
                        continue
                    self.futures[dup.task_id] = fut
                with self._qlock:
                    self._queue.appendleft(dup)

    # -- fault injection ----------------------------------------------------
    def kill_executor(self, index: int = 0) -> str:
        """Hard-kill the index-th executor (Fig. 7 fault experiment)."""
        with self._exlock:
            eid = sorted(self.executors)[index]
            ex = self.executors[eid]
        ex.kill()
        return eid

    def kill(self) -> None:
        """Simulated whole-endpoint death (site outage): the manager loop
        halts, heartbeats stop, and every executor dies with its in-flight
        work. The Forwarder's watchdog re-routes stranded tasks."""
        self._alive = False
        for ex in self._executor_list():
            ex.kill()

    # -- lifecycle -----------------------------------------------------------
    def shutdown(self) -> None:
        self._alive = False
        self._manager.join(timeout=2.0)
        for ex in self._executor_list():
            ex.shutdown()
        with self._exlock:
            self.executors.clear()

    def wait_idle(self, timeout: float = 30.0) -> bool:
        """Wait until queue and all executors are drained."""
        t0 = time.monotonic()
        while time.monotonic() - t0 < timeout:
            busy = self.queue_depth() or any(
                len(e.in_flight) or e.queued_tasks() for e in self._executor_list()
            )
            if not busy:
                return True
            time.sleep(0.005)
        return False

    def stats(self) -> dict:
        return {
            "endpoint_id": self.endpoint_id,
            "name": self.name,
            "queue_depth": self.queue_depth(),
            "completed": self.completed,
            "requeued": self.requeued,
            "lost_executors": self.lost_executors,
            "executors": {ex.executor_id: ex.stats() for ex in self._executor_list()},
            "p95_latency_s": self.tracker.p95(),
            "autoscaler": self.autoscaler.stats(),
        }
