"""funcJAX core: the paper's FaaS platform (funcX) as a JAX-native runtime.

Public API:
    FunctionService, Forwarder, Endpoint, TaskFuture, TokenAuthority, Flow,
    TaskBatch, ResultBatch, BatchCoalescer, MetricsRegistry, Autoscaler,
    Journal, ResultStore, wait, get_result, DataRef, FileSystemStore,
    InMemoryStore, TaskPredictor, ShardedForwarder, FairnessPolicy,
    AdmissionError, TenantLedger, SessionRouter
"""
from .auth import (  # noqa: F401
    SCOPE_ADMIN,
    SCOPE_INVOKE,
    SCOPE_REGISTER_ENDPOINT,
    SCOPE_REGISTER_FUNCTION,
    AuthError,
    TenantProfile,
    Token,
    TokenAuthority,
)
from .automation import (  # noqa: F401
    ActionStep,
    DataArrivalEvent,
    Event,
    EventBus,
    Flow,
    FlowRun,
    TimerEvent,
    TimerSource,
    Trigger,
    Workflow,
    WorkflowNode,
    WorkflowRun,
)
from .autoscaler import (  # noqa: F401
    Autoscaler,
    LatencySLOPolicy,
    ScalingDecision,
    ScalingObservation,
    ScalingPolicy,
    TargetQueueDepthPolicy,
    make_policy,
)
from .batching import MicroBatcher, stack_payloads, unstack_results  # noqa: F401
from .client import (  # noqa: F401
    ALL_COMPLETED,
    ALWAYS,
    ANY_COMPLETED,
    get_result,
    wait,
)
from .containers import (  # noqa: F401
    CapabilityError,
    ContainerPool,
    ContainerSpec,
    ResourceSpec,
    default_container_spec,
)
from .datastore import (  # noqa: F401
    DEFAULT_SPILL_THRESHOLD,
    DataRef,
    FileSystemStore,
    InMemoryStore,
    ObjectStore,
    get_store,
    prefetch_refs,
    register_store,
    reset_store_registry,
    resolve_packed,
    resolve_payload,
    scan_refs,
    spill_payload,
)
from .endpoint import Endpoint  # noqa: F401
from .executor import Executor  # noqa: F401
from .fairness import (  # noqa: F401
    ANONYMOUS,
    AdmissionError,
    DeficitRoundRobin,
    FairnessPolicy,
    TenantLedger,
)
from .forwarder import (  # noqa: F401
    ENDPOINT_POLICIES,
    EndpointRecord,
    Forwarder,
    SessionRouter,
    ShardedForwarder,
    shard_of,
)
from .futures import TaskEnvelope, TaskFuture, TaskState  # noqa: F401
from .heartbeat import HeartbeatMonitor, LatencyTracker  # noqa: F401
from .interchange import (  # noqa: F401
    BatchCoalescer,
    ResultBatch,
    TaskBatch,
    iter_frames,
    new_batch_id,
)
from .journal import (  # noqa: F401
    Journal,
    JournalState,
    ResultStore,
    ResumeReport,
    RunJournalEntry,
    TaskJournalEntry,
)
from .memoization import MemoCache  # noqa: F401
from .metrics import (  # noqa: F401
    BYTES_BUCKETS,
    LATENCY_BUCKETS_S,
    SIZE_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    merged_snapshot,
)
from .predictor import (  # noqa: F401
    RuntimePredictor,
    TaskPredictor,
    TransferPredictor,
)
from .provider import (  # noqa: F401
    LocalThreadProvider,
    Provider,
    ProviderSpec,
    SlurmProvider,
    TPUPodProvider,
)
from .registry import FunctionRegistry, RegisteredFunction, hash_function  # noqa: F401
from .scheduler import Scheduler  # noqa: F401
from .serializer import packb, payload_hash, unpackb  # noqa: F401
from .service import FunctionService, Invocation  # noqa: F401
from .warming import WarmPool  # noqa: F401
from .worker import Worker  # noqa: F401
