"""Scoped-token authentication (Globus Auth analogue).

funcX outsources auth to Globus Auth: services are resource servers with
scopes (e.g. ``register_function``) and clients present delegated tokens.
Here a :class:`TokenAuthority` plays the identity provider: it mints
HMAC-signed tokens carrying an identity + scope set, and the
:class:`FunctionService` verifies scope membership per API call. Endpoints
register as clients with the ``register_endpoint`` scope, mirroring funcX's
client_id/secret registration.
"""
from __future__ import annotations

import hashlib
import hmac
import os
import time
from dataclasses import dataclass
from typing import Iterable, Optional

from . import serializer

# Canonical scopes (paper §5.7 uses urn:globus:auth:scope:funcx.org:*)
SCOPE_REGISTER_FUNCTION = "register_function"
SCOPE_INVOKE = "invoke"
SCOPE_REGISTER_ENDPOINT = "register_endpoint"
SCOPE_ADMIN = "admin"
ALL_SCOPES = (
    SCOPE_REGISTER_FUNCTION,
    SCOPE_INVOKE,
    SCOPE_REGISTER_ENDPOINT,
    SCOPE_ADMIN,
)


class AuthError(PermissionError):
    pass


@dataclass(frozen=True)
class TenantProfile:
    """Fabric-level scheduling profile for one identity (the hosted service's
    per-user registration record): `quota` caps the tenant's outstanding
    tasks fabric-wide (None = unlimited; admission rejects beyond it with
    ``retry_after``), `weight` is its deficit-round-robin fair-share ratio.
    Consumed by :class:`~repro.core.fairness.FairnessPolicy` via
    ``bind_profiles``."""

    identity: str
    quota: Optional[int] = None
    weight: float = 1.0


@dataclass(frozen=True)
class Token:
    identity: str
    scopes: tuple
    issued_at: float
    expires_at: float
    signature: bytes

    def to_bytes(self) -> bytes:
        return serializer.packb(
            {
                "identity": self.identity,
                "scopes": list(self.scopes),
                "issued_at": self.issued_at,
                "expires_at": self.expires_at,
                "signature": self.signature,
            }
        )

    @staticmethod
    def from_bytes(data: bytes) -> "Token":
        d = serializer.unpackb(data)
        return Token(
            identity=d["identity"],
            scopes=tuple(d["scopes"]),
            issued_at=d["issued_at"],
            expires_at=d["expires_at"],
            signature=d["signature"],
        )


def _payload_bytes(identity: str, scopes: Iterable[str], issued_at: float, expires_at: float) -> bytes:
    return serializer.packb(
        {"identity": identity, "scopes": sorted(scopes), "ia": issued_at, "ea": expires_at}
    )


class TokenAuthority:
    """Mints and verifies scoped tokens. One per deployment (the 'Globus')."""

    def __init__(self, secret: Optional[bytes] = None):
        self._secret = secret if secret is not None else os.urandom(32)
        self._profiles: dict[str, TenantProfile] = {}

    # -- tenant profiles (fairness tier) ---------------------------------
    def set_tenant_profile(
        self, identity: str, quota: Optional[int] = None, weight: float = 1.0
    ) -> TenantProfile:
        """Declare (or replace) the scheduling profile for `identity`."""
        prof = TenantProfile(identity=identity, quota=quota, weight=weight)
        self._profiles[identity] = prof
        return prof

    def tenant_profile(self, identity: str) -> Optional[TenantProfile]:
        return self._profiles.get(identity)

    def tenant_profiles(self) -> dict[str, TenantProfile]:
        return dict(self._profiles)

    def issue(
        self,
        identity: str,
        scopes: Iterable[str] = (SCOPE_INVOKE,),
        ttl_s: float = 3600.0,
    ) -> Token:
        scopes = tuple(sorted(set(scopes)))
        for s in scopes:
            if s not in ALL_SCOPES:
                raise AuthError(f"unknown scope {s!r}")
        now = time.time()
        sig = hmac.new(
            self._secret, _payload_bytes(identity, scopes, now, now + ttl_s), hashlib.sha256
        ).digest()
        return Token(identity, scopes, now, now + ttl_s, sig)

    def verify(self, token: Optional[Token], required_scope: str) -> str:
        """Returns the authenticated identity; raises AuthError otherwise."""
        if token is None:
            raise AuthError("no token supplied")
        expected = hmac.new(
            self._secret,
            _payload_bytes(token.identity, token.scopes, token.issued_at, token.expires_at),
            hashlib.sha256,
        ).digest()
        if not hmac.compare_digest(expected, token.signature):
            raise AuthError("bad token signature")
        if time.time() > token.expires_at:
            raise AuthError("token expired")
        if required_scope not in token.scopes and SCOPE_ADMIN not in token.scopes:
            raise AuthError(f"token lacks scope {required_scope!r}")
        return token.identity
