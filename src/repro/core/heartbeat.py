"""Heartbeats + watchdog (paper §5.3, §6.3).

Executors emit heartbeats; the endpoint manager's watchdog marks an executor
dead after `threshold` missed intervals, requeues its in-flight tasks, and
asks the provider for a replacement. The fault-tolerance benchmark (Fig. 7)
drives exactly this machinery.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

from typing import Dict, List, Optional


@dataclass
class HeartbeatRecord:
    last_seen: float
    count: int = 0
    suspended: bool = False


class HeartbeatMonitor:
    def __init__(self, interval_s: float = 2.0, threshold: float = 2.0):
        """`threshold` is in heartbeat intervals (paper uses 2s heartbeats)."""
        self.interval_s = interval_s
        self.threshold = threshold
        self._lock = threading.Lock()
        self._records: Dict[str, HeartbeatRecord] = {}

    def register(self, executor_id: str, now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        with self._lock:
            self._records[executor_id] = HeartbeatRecord(last_seen=now)

    def beat(self, executor_id: str, now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        with self._lock:
            rec = self._records.get(executor_id)
            if rec is None:
                self._records[executor_id] = HeartbeatRecord(last_seen=now, count=1)
            else:
                rec.last_seen = now
                rec.count += 1

    def deregister(self, executor_id: str) -> None:
        with self._lock:
            self._records.pop(executor_id, None)

    def suspend(self, executor_id: str) -> None:
        """Paper: manager suspends executors to prevent further scheduling."""
        with self._lock:
            rec = self._records.get(executor_id)
            if rec is not None:
                rec.suspended = True

    def is_suspended(self, executor_id: str) -> bool:
        with self._lock:
            rec = self._records.get(executor_id)
            return bool(rec and rec.suspended)

    def dead(self, now: Optional[float] = None) -> List[str]:
        """Executor ids whose heartbeat is older than threshold intervals."""
        now = time.monotonic() if now is None else now
        limit = self.interval_s * self.threshold
        with self._lock:
            return [
                eid
                for eid, rec in self._records.items()
                if (now - rec.last_seen) > limit and not rec.suspended
            ]

    def snapshot(self) -> dict:
        with self._lock:
            return {
                eid: {"age": time.monotonic() - r.last_seen, "count": r.count, "suspended": r.suspended}
                for eid, r in self._records.items()
            }


class LatencyTracker:
    """Rolling latency stats used for straggler detection (speculative
    re-execution triggers at p95 * multiplier)."""

    def __init__(self, window: int = 256):
        self.window = window
        self._lock = threading.Lock()
        self._samples: List[float] = []

    def record(self, latency_s: float) -> None:
        with self._lock:
            self._samples.append(latency_s)
            if len(self._samples) > self.window:
                self._samples = self._samples[-self.window :]

    def p95(self) -> Optional[float]:
        with self._lock:
            if len(self._samples) < 8:
                return None
            s = sorted(self._samples)
            return s[int(0.95 * (len(s) - 1))]

    def count(self) -> int:
        with self._lock:
            return len(self._samples)
