"""Typed container pools: the heterogeneous execution tier (paper §5.3–5.4, §8).

The paper promises that functions are "offloaded to specialized accelerators"
and run inside managed containers whose workers "persist within containers";
resource-aware scheduling is its named future work (§8). The journal funcX
follow-up makes container management first-class: an endpoint hosts several
*container types*, each with its own warm worker pool, and tasks carry the
capabilities they require so the fabric can route them only where they can
run.

This module defines that tier:

- :class:`ContainerSpec` — one container type an executor can host: a name
  (the warm-cache variant key), the capability set it provides (``{"cpu"}``,
  ``{"cpu", "jit"}``, ...), pool bounds, and a memory hint.
- :class:`ResourceSpec` — what a registered function *requires*: capabilities
  that must all be present, plus a preferred container name tasks default
  into when the invocation doesn't name one.
- :class:`ContainerPool` — a typed worker pool with its own inbox whose
  workers persist within that container (paper §5.3). Pools resize on
  demand: workers spin up when matching tasks arrive (bounded by
  ``max_workers``) and shrink back to ``min_workers`` after a keep-alive
  idle period, unified with the :class:`~repro.core.warming.WarmPool`
  TTL semantics.
- :class:`CapabilityError` — raised (or delivered through the task future)
  when no live endpoint/pool satisfies a task's requirements. Incapable
  dispatch fails fast instead of timing out in a watchdog.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Iterable, List, Optional

from .futures import TaskEnvelope
from .worker import Worker


class CapabilityError(RuntimeError):
    """No live endpoint / container pool satisfies a task's ResourceSpec."""


def _as_capability_set(caps: Optional[Iterable[str]]) -> frozenset:
    if caps is None:
        return frozenset()
    if isinstance(caps, str):  # a lone "tpu" is a 1-capability set, not chars
        return frozenset({caps})
    return frozenset(caps)


@dataclass(frozen=True)
class ContainerSpec:
    """One container type an executor can host.

    ``name`` doubles as the warm-cache variant key — tasks executed in this
    container warm ``(function_id, name)`` entries. ``capabilities`` is what
    the pool *provides*; a task can run here iff its required capabilities
    are a subset. ``min_workers`` workers persist for the life of the
    executor; demand grows the pool up to ``max_workers`` and the keep-alive
    shrinks it back. ``memory_hint_mb`` is advisory (surfaces in stats and
    provider submit scripts; nothing in-process enforces it).
    """

    name: str
    capabilities: frozenset = frozenset({"cpu"})
    min_workers: int = 0
    max_workers: int = 4
    memory_hint_mb: int = 0

    def __post_init__(self) -> None:
        object.__setattr__(self, "capabilities", _as_capability_set(self.capabilities))
        if self.max_workers < 1:
            raise ValueError(f"container {self.name!r}: max_workers must be >= 1")
        if not 0 <= self.min_workers <= self.max_workers:
            raise ValueError(
                f"container {self.name!r}: need 0 <= min_workers <= max_workers, "
                f"got {self.min_workers}/{self.max_workers}"
            )

    def provides(self, required: Iterable[str]) -> bool:
        return _as_capability_set(required) <= self.capabilities


def default_container_spec(workers: int, name: str = "default") -> ContainerSpec:
    """The homogeneous-endpoint spec: a fixed-size cpu pool (seed parity)."""
    return ContainerSpec(
        name=name,
        capabilities=frozenset({"cpu"}),
        min_workers=workers,
        max_workers=workers,
    )


@dataclass(frozen=True)
class ResourceSpec:
    """What a registered function requires from the fabric.

    ``capabilities`` must all be provided by the chosen container pool;
    ``preferred_container`` names the container variant tasks default into
    when the invocation leaves ``container="default"``.
    """

    capabilities: frozenset = frozenset()
    preferred_container: Optional[str] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "capabilities", _as_capability_set(self.capabilities))

    def satisfied_by(self, provided: Iterable[str]) -> bool:
        return self.capabilities <= _as_capability_set(provided)


class ContainerPool:
    """A typed worker pool: one inbox, workers that persist within the
    container (paper §5.3), demand-driven sizing.

    Workers block on the inbox (no timeout-poll), so idle pools burn no CPU;
    retirement delivers one stop-sentinel per surplus worker through the same
    inbox. Sizing is demand-driven: ``submit()`` spins up as many workers as
    the backlog needs (up to ``spec.max_workers``) and ``shrink_idle()``
    retires the surplus back to ``spec.min_workers`` once the pool has been
    continuously idle for the keep-alive period — the container analogue of
    the WarmPool's TTL on compiled executables.
    """

    def __init__(
        self,
        spec: ContainerSpec,
        executor_id: str,
        outbox: "queue.Queue",
        registry,
        warm_pool,
    ):
        self.spec = spec
        self.executor_id = executor_id
        self.outbox = outbox
        self.registry = registry
        self.warm_pool = warm_pool
        self.inbox: "queue.Queue[TaskEnvelope]" = queue.Queue()
        self._lock = threading.Lock()
        self._workers: List[Worker] = []
        self._counter = 0
        self._alive = True
        # STOP sentinels enqueued but not yet consumed. Every capacity and
        # backlog computation subtracts these: a sentinel in the inbox is not
        # work, and an alive worker that will consume one is not capacity.
        # (Without this, a submit racing a shrink sees doomed workers as
        # live, declines to spin up, and strands its task in a pool whose
        # workers all retire.)
        self._pending_stops = 0
        self.spinups = 0
        self.shrinks = 0
        # becomes "idle since": refreshed while the pool has work, so the
        # keep-alive clock starts when the last task drains, not when the
        # first arrived
        self._idle_since = time.monotonic()
        if spec.min_workers:
            with self._lock:
                self._spin_up(spec.min_workers)

    # -- sizing -----------------------------------------------------------
    def _note_stop_consumed(self) -> None:
        """Worker callback: a STOP sentinel left the inbox."""
        with self._lock:
            self._pending_stops = max(0, self._pending_stops - 1)

    def _alive_count(self) -> int:
        return sum(1 for w in self._workers if w.is_alive())

    def _effective_live(self) -> int:
        """Workers that will still be here once pending sentinels land."""
        return max(0, self._alive_count() - self._pending_stops)

    def _spin_up(self, n: int) -> int:
        """Start up to n workers (bounded by spec.max_workers net of workers
        already doomed by pending sentinels). Lock held by caller."""
        started = 0
        for _ in range(n):
            self._workers = [w for w in self._workers if w.is_alive()]
            if self._effective_live() >= self.spec.max_workers:
                break
            w = Worker(
                worker_id=f"{self.executor_id}/{self.spec.name}/w{self._counter}",
                inbox=self.inbox,
                outbox=self.outbox,
                registry=self.registry,
                warm_pool=self.warm_pool,
                on_stop=self._note_stop_consumed,
            )
            self._counter += 1
            self._workers.append(w)
            w.start()
            started += 1
        self.spinups += started
        return started

    def live_workers(self) -> int:
        return sum(1 for w in self._workers if w.is_alive())

    def idle_workers(self) -> int:
        with self._lock:
            idle = sum(1 for w in self._workers if w.is_alive() and not w.busy)
            return max(0, idle - self._pending_stops)

    def busy_workers(self) -> int:
        with self._lock:
            return sum(1 for w in self._workers if w.is_alive() and w.busy)

    def queued(self) -> int:
        """Task backlog: inbox size net of pending stop sentinels."""
        with self._lock:
            return max(0, self.inbox.qsize() - self._pending_stops)

    def free_capacity(self, prefetch: int = 0) -> int:
        """Tasks this pool will absorb right now: idle workers, plus workers
        it can still spin up on demand, plus the prefetch allowance, minus
        the local backlog — all net of workers doomed by pending sentinels."""
        if not self._alive:
            return 0
        with self._lock:
            alive = self._alive_count()
            idle = sum(1 for w in self._workers if w.is_alive() and not w.busy)
            effective_idle = max(0, idle - self._pending_stops)
            effective_live = max(0, alive - self._pending_stops)
            headroom = self.spec.max_workers - effective_live
            backlog = max(0, self.inbox.qsize() - self._pending_stops)
        return max(0, effective_idle + headroom + prefetch - backlog)

    def submit(self, envs: List[TaskEnvelope]) -> None:
        """Queue tasks and grow the pool to meet the backlog (demand-driven
        spin-up, paper §5.4 'managed elasticity' at container granularity)."""
        for env in envs:
            self.inbox.put(env)
        with self._lock:
            self._idle_since = time.monotonic()
            busy = sum(1 for w in self._workers if w.is_alive() and w.busy)
            backlog = max(0, self.inbox.qsize() - self._pending_stops)
            want = min(self.spec.max_workers,
                       max(self.spec.min_workers, busy + backlog))
            if want > self._effective_live():
                self._spin_up(want - self._effective_live())

    def shrink_idle(self, keep_alive_s: float, now: Optional[float] = None) -> int:
        """Retire surplus workers after a continuous idle keep-alive period.
        Returns the number of workers retired."""
        now = time.monotonic() if now is None else now
        with self._lock:
            busy = sum(1 for w in self._workers if w.is_alive() and w.busy)
            if busy or self.inbox.qsize() > self._pending_stops:
                self._idle_since = now  # still working: keep-alive re-arms
                return 0
            live = self._effective_live()
            if live <= self.spec.min_workers:
                return 0
            if now - self._idle_since < keep_alive_s:
                return 0
            surplus = live - self.spec.min_workers
            # one sentinel per surplus worker: whichever workers consume them
            # exit; the next submit's spin-up re-grows if load returns
            for _ in range(surplus):
                self.inbox.put(Worker.STOP)
            self._pending_stops += surplus
            self.shrinks += surplus
            return surplus

    # -- lifecycle --------------------------------------------------------
    def drain_queued(self) -> List[TaskEnvelope]:
        """Pull every queued task back out (watchdog recovery path)."""
        drained: List[TaskEnvelope] = []
        while True:
            try:
                item = self.inbox.get_nowait()
            except queue.Empty:
                return drained
            if item is Worker.STOP:
                self._note_stop_consumed()
            else:
                drained.append(item)

    def kill(self) -> None:
        """Simulated node failure: workers vanish without reporting. Idle
        workers block on the inbox, so each alive worker also gets a wake-up
        sentinel — without it a killed pool would strand its idle workers as
        permanently-blocked threads pinning the pool and registry. A worker
        that wakes on a real task drops it unexecuted (``_drop_inflight``);
        the watchdog recovers it from the in-flight bookkeeping."""
        self._alive = False
        with self._lock:
            alive = [w for w in self._workers if w.is_alive()]
            for w in alive:
                w.simulate_failure()
            self._pending_stops += len(alive)
            for _ in alive:
                self.inbox.put(Worker.STOP)

    def stop(self, join: bool = True) -> None:
        """Graceful retirement: one sentinel per worker, then join the idle
        ones (a worker mid-task finishes and exits on its own)."""
        self._alive = False
        with self._lock:
            workers = [w for w in self._workers if w.is_alive()]
            self._pending_stops += len(workers)
        for w in workers:
            w.stop()
        if join:
            for w in workers:
                if not w.busy:
                    w.join(timeout=1.0)

    def stats(self) -> dict:
        return {
            "container": self.spec.name,
            "capabilities": sorted(self.spec.capabilities),
            "workers": self.live_workers(),
            "idle": self.idle_workers(),
            "queued": self.queued(),
            "spinups": self.spinups,
            "shrinks": self.shrinks,
            "memory_hint_mb": self.spec.memory_hint_mb,
        }
