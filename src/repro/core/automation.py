"""Workflow automation: DAG engine + event triggers (paper §5.6, §7).

funcX exposes start/cancel/status endpoints so automation platforms (Globus
Automate) can run functions as flow steps, and the paper's five §7 science
scenarios are all multi-step pipelines "triggered by events (e.g., arrival of
new data)". This module provides that layer on top of the fabric:

- :class:`Workflow` — a DAG of :class:`WorkflowNode`\\ s. Nodes declare
  upstream dependencies and receive the merged upstream results; every node
  that becomes ready in the same scheduling round is submitted through
  :meth:`FunctionService.run_many` as ONE batch, so sibling branches ride a
  single TaskBatch frame through the Forwarder. Scheduling is iterative
  (a drain-loop driver, never recursion through done-callbacks), supports
  per-node retry (`max_attempts`) and on-error policies (`fail` / `skip`),
  and passes warm-affinity hints so a node's children prefer the endpoint
  holding the parent's warm function.
- :class:`EventBus` / :class:`Trigger` — publish/subscribe event routing with
  :class:`DataArrivalEvent` and :class:`TimerEvent` sources; a Trigger rule
  starts one workflow run per matching event (the "arrival of new data"
  pattern).
- :class:`Flow` — the original linear ActionProvider surface, kept as a thin
  shim over :class:`Workflow` so existing callers keep working.

Metrics (recorded in the service's fabric registry): ``workflow.runs``
(counter, labeled ``state=started|succeeded|failed|cancelled``),
``workflow.nodes_completed``, ``workflow.node_retries``,
``workflow.node_latency_s`` (histogram), ``trigger.fired`` (counter, labeled
by trigger name). See docs/workflows.md.
"""
from __future__ import annotations

import threading
import time
import uuid
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from . import serializer
from .auth import Token
from .futures import TaskFuture
from .journal import RunJournalEntry
from .metrics import MetricsRegistry
from .service import FunctionService, Invocation

# Run / node terminal states are plain strings (REST-shaped, like the paper's
# ActionProvider status document).
ACTIVE, SUCCEEDED, FAILED, CANCELLED = "ACTIVE", "SUCCEEDED", "FAILED", "CANCELLED"
PENDING, RUNNING, SKIPPED = "PENDING", "RUNNING", "SKIPPED"

ON_ERROR_POLICIES = ("fail", "skip")


@dataclass
class WorkflowNode:
    """One DAG node: run `function_id` once every upstream dep has finished.

    ``prepare(document, upstream)`` maps the run's initial document plus the
    dict of upstream results (``{dep_name: result}``) to this node's payload.
    Default when omitted: no deps → the document; one dep → that dep's
    result; several deps → the upstream dict itself (fan-in merge).

    ``max_attempts`` is workflow-level retry (re-submission through the
    service); ``max_retries`` is the transport-level retry the endpoint
    applies before the failure ever reaches the workflow. ``on_error="skip"``
    records ``fallback`` as the node's result and lets downstream nodes
    proceed; ``"fail"`` (default) fails the whole run.
    """

    name: str
    function_id: str
    deps: Sequence[str] = ()
    prepare: Optional[Callable[[Any, Dict[str, Any]], Any]] = None
    endpoint_id: Optional[str] = None
    container: str = "default"
    requirements: Optional[Sequence[str]] = None  # capability override (None = function's)
    memoize: bool = False
    max_attempts: int = 1
    max_retries: int = 2
    on_error: str = "fail"
    fallback: Any = None

    def __post_init__(self) -> None:
        if self.on_error not in ON_ERROR_POLICIES:
            raise ValueError(
                f"node {self.name!r}: on_error {self.on_error!r} not in {ON_ERROR_POLICIES}"
            )
        if self.max_attempts < 1:
            raise ValueError(f"node {self.name!r}: max_attempts must be >= 1")

    def payload_for(self, document: Any, upstream: Dict[str, Any]) -> Any:
        if self.prepare is not None:
            return self.prepare(document, upstream)
        if not self.deps:
            return document
        if len(self.deps) == 1:
            return upstream[self.deps[0]]
        return dict(upstream)


class WorkflowRun:
    """State of one workflow execution. All mutation happens under ``_lock``;
    progression is driven by the owning :class:`Workflow`'s drain loop."""

    def __init__(self, workflow: "Workflow", document: Any,
                 metrics: Optional[MetricsRegistry] = None):
        self.run_id = f"wfrun-{uuid.uuid4().hex[:8]}"
        self.workflow = workflow
        self.document = document
        self.state = ACTIVE
        self.node_states: Dict[str, str] = {n: PENDING for n in workflow.nodes}
        self.results: Dict[str, Any] = {}
        self.node_endpoint: Dict[str, Optional[str]] = {}
        self.attempts: Dict[str, int] = {n: 0 for n in workflow.nodes}
        self.error: Optional[str] = None
        self.history: List[dict] = []
        self.inflight: Dict[str, Tuple[TaskFuture, Callable]] = {}
        self._indegree: Dict[str, int] = {
            n: len(node.deps) for n, node in workflow.nodes.items()
        }
        self._remaining = len(workflow.nodes)
        self._events: deque = deque()
        self._lock = threading.RLock()
        self._draining = False
        self._done = threading.Event()
        self._metrics = metrics
        self._journal = None  # bound by Workflow.start/resume when durable

    # -- consumer surface --------------------------------------------------
    def done(self) -> bool:
        return self._done.is_set()

    def output(self) -> Any:
        """Merged result of the DAG's sink nodes (single sink → its bare
        result; several sinks → ``{name: result}``)."""
        sinks = self.workflow.sinks
        with self._lock:
            if len(sinks) == 1:
                return self.results.get(sinks[0])
            return {name: self.results.get(name) for name in sinks}

    def wait(self, timeout: float = 60.0) -> Any:
        if not self._done.wait(timeout):
            raise TimeoutError(f"workflow run {self.run_id} still active")
        if self.state == FAILED:
            raise RuntimeError(f"workflow run {self.run_id} failed: {self.error}")
        if self.state == CANCELLED:
            raise RuntimeError(f"workflow run {self.run_id} was cancelled")
        return self.output()

    def status(self) -> dict:
        with self._lock:
            return {
                "run_id": self.run_id,
                "workflow": self.workflow.name,
                "state": self.state,
                "nodes": dict(self.node_states),
                "error": self.error,
                "history": list(self.history),
            }

    def cancel(self) -> None:
        """Cancel the run: nothing further launches, and every in-flight
        future is detached — its task may still finish on the endpoint, but
        its completion no longer drives this run."""
        with self._lock:
            if self.state != ACTIVE:
                return
            self.state = CANCELLED
            inflight = list(self.inflight.items())
            self.inflight.clear()
            for name, st in self.node_states.items():
                if st in (PENDING, RUNNING):
                    self.node_states[name] = CANCELLED
            self._events.clear()
        for _, (fut, cb) in inflight:
            fut.remove_done_callback(cb)
        if self._journal is not None:  # a cancelled run must not resume
            self._journal.append(
                "run", "finished", run_id=self.run_id, state=CANCELLED
            )
        if self._metrics is not None:
            self._metrics.counter("workflow.runs", {"state": "cancelled"}).inc()
        self._done.set()


class Workflow:
    """A DAG of :class:`WorkflowNode`\\ s, validated at construction
    (unique names, known deps, acyclic). A Workflow is stateless across
    runs — the same instance can drive many concurrent :class:`WorkflowRun`\\ s.
    """

    def __init__(self, nodes: Sequence[WorkflowNode], name: str = "workflow"):
        self.name = name
        self.nodes: Dict[str, WorkflowNode] = {}
        for node in nodes:
            if node.name in self.nodes:
                raise ValueError(f"duplicate node name {node.name!r}")
            self.nodes[node.name] = node
        self.children: Dict[str, List[str]] = {n: [] for n in self.nodes}
        for node in nodes:
            for dep in node.deps:
                if dep not in self.nodes:
                    raise ValueError(
                        f"node {node.name!r} depends on unknown node {dep!r}"
                    )
                self.children[dep].append(node.name)
        self._order = self._toposort()
        self.sinks: List[str] = [n for n in self._order if not self.children[n]]

    def _toposort(self) -> List[str]:
        indeg = {n: len(node.deps) for n, node in self.nodes.items()}
        frontier = deque(n for n, d in indeg.items() if d == 0)
        order: List[str] = []
        while frontier:
            n = frontier.popleft()
            order.append(n)
            for child in self.children[n]:
                indeg[child] -= 1
                if indeg[child] == 0:
                    frontier.append(child)
        if len(order) != len(self.nodes):
            cyclic = sorted(n for n, d in indeg.items() if d > 0)
            raise ValueError(f"workflow has a dependency cycle through {cyclic}")
        return order

    def topological_order(self) -> List[str]:
        return list(self._order)

    # -- ActionProvider interface: start / status / cancel -----------------
    def start(
        self,
        service: FunctionService,
        document: Any = None,
        token: Optional[Token] = None,
    ) -> WorkflowRun:
        run = WorkflowRun(self, document, metrics=service.metrics)
        run._journal = service.journal
        service.metrics.counter("workflow.runs", {"state": "started"}).inc()
        if service.journal is not None:
            try:
                packed_doc = serializer.packb(document)
            except Exception:
                packed_doc = None  # unserializable document: run not resumable
            service.journal.append(
                "run", "started", run_id=run.run_id, workflow=self.name,
                document=packed_doc, nodes=list(self._order),
            )
        if not self.nodes:
            run.state = SUCCEEDED
            run._done.set()
            service.metrics.counter("workflow.runs", {"state": "succeeded"}).inc()
            if service.journal is not None:
                service.journal.append(
                    "run", "finished", run_id=run.run_id, state=SUCCEEDED
                )
            return run
        ready = [n for n in self._order if not self.nodes[n].deps]
        # reraise: a submission error in the caller's own start() frame
        # (unknown function, bad token) surfaces synchronously, exactly as
        # the seed Flow did — only callback-thread resubmissions may not throw
        self._submit(service, run, ready, token, reraise=True)
        return run

    @staticmethod
    def status(run: WorkflowRun) -> dict:
        return run.status()

    @staticmethod
    def cancel(run: WorkflowRun) -> None:
        run.cancel()

    @staticmethod
    def wait(run: WorkflowRun, timeout: float = 60.0) -> Any:
        return run.wait(timeout)

    # -- durability --------------------------------------------------------
    def resume(
        self,
        service: FunctionService,
        entry: RunJournalEntry,
        token: Optional[Token] = None,
    ) -> WorkflowRun:
        """Rehydrate a journaled run and re-execute ONLY its unfinished
        nodes. Committed node results (and skips) are replayed into the run
        verbatim; everything whose dependencies are thereby satisfied is
        re-submitted. Usually reached through
        :meth:`FunctionService.resume`, which matches journal entries to
        workflow definitions by name."""
        document = (
            serializer.unpackb(entry.document)
            if entry.document is not None else None
        )
        run = WorkflowRun(self, document, metrics=service.metrics)
        run.run_id = entry.run_id  # identity survives the restart
        run._journal = service.journal
        service.metrics.counter("workflow.runs", {"state": "resumed"}).inc()
        with run._lock:
            for name, packed in entry.node_results.items():
                if name not in self.nodes:
                    continue  # journal from an older definition of this DAG
                if entry.node_skipped.get(name):
                    run.results[name] = self.nodes[name].fallback
                    run.node_states[name] = SKIPPED
                elif packed is not None:
                    run.results[name] = serializer.unpackb(packed)
                    run.node_states[name] = SUCCEEDED
                else:
                    continue  # completed but result not journaled: re-run
                run.history.append({
                    "node": name, "state": run.node_states[name],
                    "attempt": 0, "replayed": True,
                })
                self._advance_children(run, name)
            ready = [
                n for n in self._order
                if run.node_states[n] == PENDING and run._indegree[n] == 0
            ]
            finished = run._remaining == 0
        if service.journal is not None:
            service.journal.append("run", "resumed", run_id=run.run_id)
        if finished:
            self._finish(service, run, SUCCEEDED)
        else:
            self._submit(service, run, ready, token)
        return run

    # -- scheduler ---------------------------------------------------------
    def _submit(
        self,
        service: FunctionService,
        run: WorkflowRun,
        names: Sequence[str],
        token: Optional[Token],
        reraise: bool = False,
    ) -> None:
        """Submit every node in `names` as ONE heterogeneous batch (sibling
        branches ride a single TaskBatch frame through the Forwarder)."""
        invocations: List[Invocation] = []
        submit_names: List[str] = []
        for name in names:
            node = self.nodes[name]
            with run._lock:
                if run.state != ACTIVE:
                    return
                upstream = {dep: run.results.get(dep) for dep in node.deps}
                document = run.document
                run.attempts[name] += 1
                run.node_states[name] = RUNNING
                # warm-affinity hint: prefer the endpoint that just ran a
                # parent (it holds the warm executable for the lineage)
                hint = None
                for dep in node.deps:
                    hint = run.node_endpoint.get(dep) or hint
            try:
                payload = node.payload_for(document, upstream)
            except Exception as exc:  # prepare() itself failed
                run._events.append(("failed", name, exc))
                continue
            invocations.append(
                Invocation(
                    function_id=node.function_id,
                    payload=payload,
                    endpoint_id=node.endpoint_id,
                    container=node.container,
                    requirements=node.requirements,
                    memoize=node.memoize,
                    max_retries=node.max_retries,
                    affinity_hint=None if node.endpoint_id else hint,
                    owner=run.run_id,  # durability: this run re-drives the node
                )
            )
            submit_names.append(name)
        if invocations:
            try:
                futures = service.run_many(invocations, token=token)
            except Exception as exc:
                # a submission error (unknown function, auth failure) must
                # fail the run, not escape through the completion-callback
                # chain into whatever thread drove the parent's result
                with run._lock:
                    if run.state != ACTIVE:
                        return
                    for name in submit_names:
                        run.node_states[name] = FAILED
                        run.history.append({
                            "node": name,
                            "state": FAILED,
                            "attempt": run.attempts[name],
                            "error": repr(exc),
                        })
                    run.error = f"submission of {submit_names} failed: {exc!r}"
                self._finish(service, run, FAILED)
                if reraise:
                    raise
                return
            for name, fut in zip(submit_names, futures):
                def _cb(f: TaskFuture, name: str = name) -> None:
                    run._events.append(("done", name, f))
                    self._drain(service, run, token)

                with run._lock:
                    run.inflight[name] = (fut, _cb)
                fut.add_done_callback(_cb)
        self._drain(service, run, token)

    def _drain(
        self,
        service: FunctionService,
        run: WorkflowRun,
        token: Optional[Token],
    ) -> None:
        """Iterative event processor: the first caller becomes the driver and
        consumes the event queue to exhaustion; concurrent completions merely
        enqueue. Deep chains therefore advance in a flat loop — completion
        callbacks never recurse into submission into completion (the seed
        ``Flow._advance`` stack-overflowed on memoized 1000-step chains)."""
        with run._lock:
            if run._draining:
                return
            run._draining = True
        try:
            while True:
                with run._lock:
                    if not run._events:
                        run._draining = False
                        return
                    kind, name, obj = run._events.popleft()
                if kind == "done":
                    exc = obj.exception(0)
                    if exc is None:
                        self._node_succeeded(service, run, name, obj, token)
                    else:
                        self._node_failed(service, run, name, exc, token, obj)
                else:  # "failed": prepare() raised, no future exists
                    self._node_failed(service, run, name, obj, token, None)
        except BaseException:
            with run._lock:
                run._draining = False
            raise

    def _node_succeeded(
        self,
        service: FunctionService,
        run: WorkflowRun,
        name: str,
        future: TaskFuture,
        token: Optional[Token],
    ) -> None:
        ts = future.timestamps
        with run._lock:
            if run.state != ACTIVE:
                return
            run.inflight.pop(name, None)
            run.results[name] = future.result(0)
            run.node_states[name] = SUCCEEDED
            run.node_endpoint[name] = future.endpoint_id
            run.history.append({
                "node": name,
                "state": SUCCEEDED,
                "task_id": future.task_id,
                "attempt": run.attempts[name],
                "endpoint": future.endpoint_id,
                "latency": future.latency_breakdown(),
            })
            ready = self._advance_children(run, name)
            finished = run._remaining == 0
        if service.journal is not None:
            try:
                packed = serializer.packb(future.result(0))
            except Exception:
                packed = None  # unserializable: the node re-runs on resume
            if packed is not None:
                service.journal.append(
                    "run", "node_completed", run_id=run.run_id,
                    node=name, result=packed,
                )
        service.metrics.counter("workflow.nodes_completed").inc()
        if ts.result_ready and ts.client_submit:
            service.metrics.histogram("workflow.node_latency_s").observe(
                ts.result_ready - ts.client_submit
            )
        if finished:
            self._finish(service, run, SUCCEEDED)
        elif ready:
            self._submit(service, run, ready, token)

    def _node_failed(
        self,
        service: FunctionService,
        run: WorkflowRun,
        name: str,
        exc: BaseException,
        token: Optional[Token],
        future: Optional[TaskFuture],
    ) -> None:
        node = self.nodes[name]
        with run._lock:
            if run.state != ACTIVE:
                return
            run.inflight.pop(name, None)
            attempts = run.attempts[name]
            retry = future is not None and attempts < node.max_attempts
            run.history.append({
                "node": name,
                "state": "RETRYING" if retry else (
                    SKIPPED if node.on_error == "skip" else FAILED
                ),
                "attempt": attempts,
                "error": repr(exc),
            })
            if retry:
                run.node_states[name] = PENDING
            elif node.on_error == "skip":
                run.results[name] = node.fallback
                run.node_states[name] = SKIPPED
                ready = self._advance_children(run, name)
                finished = run._remaining == 0
            else:
                run.node_states[name] = FAILED
                run.error = f"node {name!r}: {exc!r}"
        if retry:
            service.metrics.counter("workflow.node_retries").inc()
            self._submit(service, run, [name], token)
        elif node.on_error == "skip":
            if service.journal is not None:
                service.journal.append(
                    "run", "node_skipped", run_id=run.run_id, node=name
                )
            if finished:
                self._finish(service, run, SUCCEEDED)
            elif ready:
                self._submit(service, run, ready, token)
        else:
            self._finish(service, run, FAILED)

    def _advance_children(self, run: WorkflowRun, name: str) -> List[str]:
        """Bookkeeping after a node reaches a downstream-visible terminal
        state. Must be called with ``run._lock`` held. Returns newly-ready
        children in topological order."""
        run._remaining -= 1
        ready = []
        for child in self.children[name]:
            run._indegree[child] -= 1
            if run._indegree[child] == 0:
                ready.append(child)
        return ready

    def _finish(self, service: FunctionService, run: WorkflowRun, state: str) -> None:
        with run._lock:
            if run.state != ACTIVE:
                return
            run.state = state
            inflight = list(run.inflight.items())
            run.inflight.clear()
            run._events.clear()
        for _, (fut, cb) in inflight:  # a failed run detaches its survivors
            fut.remove_done_callback(cb)
        if service.journal is not None:
            service.journal.append(
                "run", "finished", run_id=run.run_id, state=state
            )
        service.metrics.counter(
            "workflow.runs", {"state": state.lower()}
        ).inc()
        run._done.set()


# --------------------------------------------------------------------------
# Event subsystem: bus, sources, triggers
# --------------------------------------------------------------------------
class Event:
    """Base event: a topic plus an arbitrary data payload."""

    topic = "event"

    def __init__(self, data: Any = None):
        self.data = data
        self.created = time.monotonic()

    def document(self) -> Any:
        """What a triggered workflow run receives as its initial document."""
        return self.data

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{type(self).__name__}(topic={self.topic!r})"


class DataArrivalEvent(Event):
    """New data landed somewhere (the paper's 'arrival of new data' pattern:
    a detector wrote a frame, a transfer completed, a file appeared)."""

    topic = "data.arrival"

    def __init__(self, source: str, item: Any = None, metadata: Optional[dict] = None):
        super().__init__(data=item)
        self.source = source
        self.item = item
        self.metadata = metadata or {}

    def document(self) -> Any:
        return {"source": self.source, "item": self.item, "metadata": self.metadata}


class TimerEvent(Event):
    """Periodic tick from a :class:`TimerSource` (cron-style triggering)."""

    topic = "timer"

    def __init__(self, tick: int, period_s: float):
        super().__init__(data={"tick": tick, "period_s": period_s})
        self.tick = tick
        self.period_s = period_s


class EventBus:
    """Topic-keyed publish/subscribe. Dispatch is synchronous in the
    publisher's thread (sources that need isolation publish from their own
    thread, e.g. :class:`TimerSource`); a handler exception never prevents
    delivery to the remaining subscribers, but is never silent either —
    ``errors``/``last_error`` record it (plus an ``eventbus.handler_errors``
    counter when a metrics registry is attached)."""

    def __init__(self, metrics: Optional[MetricsRegistry] = None):
        self._subs: Dict[str, List[Callable[[Event], Any]]] = {}
        self._lock = threading.Lock()
        self.metrics = metrics
        self.published = 0
        self.errors = 0
        self.last_error: Optional[BaseException] = None

    def subscribe(self, topic: str, handler: Callable[[Event], Any]) -> Callable:
        with self._lock:
            self._subs.setdefault(topic, []).append(handler)
        return handler

    def unsubscribe(self, topic: str, handler: Callable[[Event], Any]) -> None:
        with self._lock:
            handlers = self._subs.get(topic, [])
            if handler in handlers:
                handlers.remove(handler)

    def attach(self, trigger: "Trigger") -> "Trigger":
        """Bind a trigger rule to its topic."""
        self.subscribe(trigger.topic, trigger.handle)
        return trigger

    def detach(self, trigger: "Trigger") -> None:
        self.unsubscribe(trigger.topic, trigger.handle)

    def publish(self, event: Event) -> int:
        """Deliver `event` to every subscriber of its topic; returns the
        number of handlers invoked."""
        with self._lock:
            handlers = list(self._subs.get(event.topic, ()))
            self.published += 1
        for handler in handlers:
            try:
                handler(event)
            except Exception as exc:  # noqa: BLE001 - one bad rule must not mute the rest
                with self._lock:
                    self.errors += 1
                    self.last_error = exc
                if self.metrics is not None:
                    self.metrics.counter("eventbus.handler_errors").inc()
        return len(handlers)


class TimerSource:
    """Publishes a :class:`TimerEvent` on `bus` every `period_s` seconds
    until stopped."""

    def __init__(self, bus: EventBus, period_s: float, max_ticks: Optional[int] = None):
        self.bus = bus
        self.period_s = period_s
        self.max_ticks = max_ticks
        self.ticks = 0
        self._halt = threading.Event()
        self._thread = threading.Thread(
            target=self._loop, name="automation/timer", daemon=True
        )

    def start(self) -> "TimerSource":
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._halt.wait(self.period_s):
            self.ticks += 1
            self.bus.publish(TimerEvent(self.ticks, self.period_s))
            if self.max_ticks is not None and self.ticks >= self.max_ticks:
                return

    def stop(self) -> None:
        self._halt.set()
        self._thread.join(timeout=2.0)


class Trigger:
    """An event→workflow rule: when a matching event arrives, start one
    workflow run with a document built from the event.

    `build_document` maps the event to the run's initial document (default:
    ``event.document()``); `predicate` optionally filters events; `once=True`
    disarms the trigger after its first firing. `fired` counts firings;
    `runs` retains recent runs, pruning *completed* ones beyond `keep_runs`
    oldest-first so a long-lived trigger (a 1 Hz timer left running for days)
    cannot grow memory without bound — in-flight runs are never dropped.
    """

    def __init__(
        self,
        workflow: Workflow,
        service: FunctionService,
        topic: str = DataArrivalEvent.topic,
        name: str = "trigger",
        build_document: Optional[Callable[[Event], Any]] = None,
        predicate: Optional[Callable[[Event], bool]] = None,
        token: Optional[Token] = None,
        once: bool = False,
        keep_runs: int = 256,
    ):
        self.workflow = workflow
        self.service = service
        self.topic = topic
        self.name = name
        self.build_document = build_document
        self.predicate = predicate
        self.token = token
        self.once = once
        self.keep_runs = keep_runs
        self.fired = 0
        self.runs: List[WorkflowRun] = []
        self._lock = threading.Lock()

    def handle(self, event: Event) -> Optional[WorkflowRun]:
        if self.predicate is not None and not self.predicate(event):
            return None
        # the lock guards only the once/fired decision: starting the workflow
        # may drive an entire memoized DAG synchronously, and a node that
        # publishes back onto the bus must not deadlock on re-entry
        with self._lock:
            if self.once and self.fired:
                return None
            self.fired += 1
        document = (
            self.build_document(event)
            if self.build_document is not None
            else event.document()
        )
        run = self.workflow.start(self.service, document, token=self.token)
        with self._lock:
            self.runs.append(run)
            if len(self.runs) > self.keep_runs:
                self.runs = (
                    [r for r in self.runs[:-self.keep_runs] if not r.done()]
                    + self.runs[-self.keep_runs:]
                )
        self.service.metrics.counter("trigger.fired", {"trigger": self.name}).inc()
        return run


# --------------------------------------------------------------------------
# Linear Flow shim (the original §5.6 ActionProvider surface)
# --------------------------------------------------------------------------
@dataclass
class ActionStep:
    function_id: str
    endpoint_id: Optional[str] = None
    # maps the flow document -> this step's payload (default: identity)
    prepare: Callable[[Any], Any] = lambda doc: doc
    # merges the step result back into the flow document (default: replace)
    merge: Callable[[Any, Any], Any] = lambda doc, result: result
    memoize: bool = False
    name: str = ""


@dataclass
class FlowRun:
    """Linear-flow view over a :class:`WorkflowRun` (kept API-compatible with
    the original dataclass: state / step_index / document / history /
    current)."""

    flow_id: str
    flow: "Flow"
    inner: WorkflowRun
    _doc: Dict[str, Any] = field(default_factory=dict)
    _final_merged: bool = False

    @property
    def state(self) -> str:
        return self.inner.state

    @property
    def step_index(self) -> int:
        with self.inner._lock:
            return sum(
                1 for s in self.inner.node_states.values() if s == SUCCEEDED
            )

    @property
    def document(self) -> Any:
        # the last step's merge has no downstream prepare() to apply it, so
        # it lands lazily once the run has succeeded (under the run lock:
        # concurrent readers must not apply a non-idempotent merge twice)
        with self.inner._lock:
            if self.inner.state == SUCCEEDED and not self._final_merged:
                last = self.flow.steps[-1]
                self._doc["doc"] = last.merge(
                    self._doc["doc"], self.inner.results[self.flow._node_names[-1]]
                )
                self._final_merged = True
            return self._doc["doc"]

    @property
    def history(self) -> List[dict]:
        out = []
        for entry in self.inner.history:
            step_name = self.flow._step_name(entry["node"])
            if entry["state"] == SUCCEEDED:
                out.append({
                    "step": step_name,
                    "task_id": entry["task_id"],
                    "latency": entry["latency"],
                })
            else:
                out.append({"step": step_name, "error": entry.get("error")})
        return out

    @property
    def current(self) -> Optional[TaskFuture]:
        with self.inner._lock:
            for fut, _ in self.inner.inflight.values():
                return fut
            return None


class Flow:
    """A linear automation flow: a chain-shaped :class:`Workflow` whose steps
    thread a single document through ``prepare``/``merge``."""

    def __init__(self, steps: List[ActionStep], name: str = "flow"):
        if not steps:
            raise ValueError("a Flow needs at least one step")
        self.steps = steps
        self.name = name
        self._node_names = [
            f"s{i}:{step.name or 'step'}" for i, step in enumerate(steps)
        ]

    def _step_name(self, node_name: str) -> str:
        idx = int(node_name.split(":", 1)[0][1:])
        return self.steps[idx].name

    # ActionProvider interface: start / status / cancel / release ----------
    def start(self, service: FunctionService, document: Any,
              token: Optional[Token] = None) -> FlowRun:
        holder = {"doc": document}
        nodes: List[WorkflowNode] = []
        for i, step in enumerate(self.steps):
            prev_step = self.steps[i - 1] if i else None
            prev_name = self._node_names[i - 1] if i else None

            def prepare(doc: Any, upstream: Dict[str, Any],
                        step: ActionStep = step,
                        prev_step: Optional[ActionStep] = prev_step,
                        prev_name: Optional[str] = prev_name) -> Any:
                if prev_step is not None:
                    holder["doc"] = prev_step.merge(holder["doc"], upstream[prev_name])
                return step.prepare(holder["doc"])

            nodes.append(WorkflowNode(
                name=self._node_names[i],
                function_id=step.function_id,
                deps=[prev_name] if prev_name is not None else (),
                prepare=prepare,
                endpoint_id=step.endpoint_id,
                memoize=step.memoize,
            ))
        # built per-start because prepare closes over this run's document
        # holder — a Flow, like a Workflow, stays reusable across runs
        inner = Workflow(nodes, name=self.name).start(service, document, token=token)
        return FlowRun(
            flow_id=f"flow-{uuid.uuid4().hex[:8]}", flow=self, inner=inner,
            _doc=holder,
        )

    @staticmethod
    def status(run: FlowRun) -> dict:
        return {"flow_id": run.flow_id, "state": run.state,
                "step": run.step_index, "history": list(run.history)}

    @staticmethod
    def cancel(run: FlowRun) -> None:
        """Cancel the flow: the in-flight future (if any) is detached so its
        completion cannot launch further steps."""
        run.inner.cancel()

    @staticmethod
    def wait(run: FlowRun, timeout: float = 60.0) -> Any:
        if not run.inner._done.wait(timeout):
            raise TimeoutError(f"flow {run.flow_id} still active")
        if run.state == FAILED:
            raise RuntimeError(f"flow failed: {run.history[-1]}")
        return run.document
