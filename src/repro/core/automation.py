"""Automation flows (paper §5.6: Globus Automate ActionProvider).

funcX exposes start/cancel/status REST endpoints so automation platforms can
run functions as flow steps. Here a :class:`Flow` is a list of
:class:`ActionStep`\\ s; each step invokes a registered function on an
endpoint, optionally transforming the running document between steps — the
event-driven pipeline pattern of the five science case studies (§7).
"""
from __future__ import annotations

import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional


from .auth import Token
from .futures import TaskFuture
from .service import FunctionService


@dataclass
class ActionStep:
    function_id: str
    endpoint_id: Optional[str] = None
    # maps the flow document -> this step's payload (default: identity)
    prepare: Callable[[Any], Any] = lambda doc: doc
    # merges the step result back into the flow document (default: replace)
    merge: Callable[[Any, Any], Any] = lambda doc, result: result
    memoize: bool = False
    name: str = ""


@dataclass
class FlowRun:
    flow_id: str
    state: str = "ACTIVE"             # ACTIVE | SUCCEEDED | FAILED | CANCELLED
    step_index: int = 0
    document: Any = None
    history: List[dict] = field(default_factory=list)
    current: Optional[TaskFuture] = None


class Flow:
    """A linear automation flow. (The paper's flows are linear sequences of
    actions; branching/eventing is left to the caller.)"""

    def __init__(self, steps: List[ActionStep], name: str = "flow"):
        self.steps = steps
        self.name = name

    # ActionProvider interface: start / status / cancel / release ----------
    def start(self, service: FunctionService, document: Any,
              token: Optional[Token] = None) -> FlowRun:
        run = FlowRun(flow_id=f"flow-{uuid.uuid4().hex[:8]}", document=document)
        self._advance(service, run, token)
        return run

    def _advance(self, service: FunctionService, run: FlowRun,
                 token: Optional[Token]) -> None:
        if run.step_index >= len(self.steps):
            run.state = "SUCCEEDED"
            run.current = None
            return
        step = self.steps[run.step_index]
        payload = step.prepare(run.document)
        fut = service.run(
            step.function_id, payload, endpoint_id=step.endpoint_id,
            memoize=step.memoize, token=token,
        )
        run.current = fut

        def _on_done(f: TaskFuture, step=step) -> None:
            if run.state == "CANCELLED":
                return
            exc = f.exception()
            if exc is not None:
                run.state = "FAILED"
                run.history.append({"step": step.name, "error": repr(exc)})
                return
            run.document = step.merge(run.document, f.result())
            run.history.append(
                {"step": step.name, "task_id": f.task_id, "latency": f.latency_breakdown()}
            )
            run.step_index += 1
            self._advance(service, run, token)

        fut.add_done_callback(_on_done)

    @staticmethod
    def status(run: FlowRun) -> dict:
        return {"flow_id": run.flow_id, "state": run.state,
                "step": run.step_index, "history": list(run.history)}

    @staticmethod
    def cancel(run: FlowRun) -> None:
        run.state = "CANCELLED"

    @staticmethod
    def wait(run: FlowRun, timeout: float = 60.0) -> Any:
        t0 = time.monotonic()
        while run.state == "ACTIVE":
            if time.monotonic() - t0 > timeout:
                raise TimeoutError(f"flow {run.flow_id} still active")
            cur = run.current
            if cur is not None:
                cur._event.wait(0.05)
            else:
                time.sleep(0.005)
        if run.state == "FAILED":
            raise RuntimeError(f"flow failed: {run.history[-1]}")
        return run.document
