"""Interchange framing: batched task flow between tiers (paper §5.3, §5.5).

The paper's headline scale (millions of tasks over 65k+ concurrent workers)
comes from moving tasks in *batches* at every hop: the interchange batches
tasks to managers, managers hand executors batches sized by advertised
capacity, and results return in batches (Fig. 8). This module provides the
shared framing for that pipeline:

- :class:`TaskBatch` — a frame of task envelopes (plus their futures at the
  fabric tier) that travels service -> forwarder -> endpoint as one unit.
- :class:`ResultBatch` — a frame of results draining executor -> endpoint.
- :class:`BatchCoalescer` — flush-on-size / flush-on-deadline accumulator
  (the ``max_batch`` / ``max_delay_s`` knobs), guaranteed to deliver every
  added item exactly once.

All four tiers ride these frames; a single-task ``run()`` is simply a batch
of one, so per-task semantics (memoization, retries, speculation, failover)
are unchanged.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Iterator, List, Optional, Sequence, Tuple

from .futures import TaskEnvelope, TaskFuture

_batch_counter = itertools.count()


def new_batch_id() -> str:
    return f"batch-{next(_batch_counter)}"


@dataclass
class TaskBatch:
    """A frame of tasks moving downstream as one unit.

    At the fabric tier (forwarder -> endpoint) ``futures`` runs parallel to
    ``envelopes``; at the endpoint -> executor hop only envelopes travel (the
    endpoint keeps the futures).
    """

    envelopes: List[TaskEnvelope]
    futures: List[TaskFuture] = field(default_factory=list)
    batch_id: str = field(default_factory=new_batch_id)
    created_at: float = field(default_factory=time.monotonic)

    def __post_init__(self) -> None:
        for env in self.envelopes:
            env.batch_id = self.batch_id

    def __len__(self) -> int:
        return len(self.envelopes)

    def __iter__(self) -> Iterator[TaskEnvelope]:
        return iter(self.envelopes)

    def pairs(self) -> List[Tuple[TaskEnvelope, TaskFuture]]:
        return list(zip(self.envelopes, self.futures))


@dataclass
class ResultBatch:
    """A frame of :class:`repro.core.worker.TaskResult`s moving upstream."""

    results: List[Any]
    batch_id: str = field(default_factory=new_batch_id)

    def __len__(self) -> int:
        return len(self.results)

    def __iter__(self) -> Iterator[Any]:
        return iter(self.results)


def iter_frames(
    pairs: Sequence[Tuple[TaskEnvelope, TaskFuture]], max_batch: int
) -> Iterator[TaskBatch]:
    """Slice routed (envelope, future) pairs into TaskBatch frames of at most
    ``max_batch`` tasks each."""
    step = max(1, int(max_batch))
    for i in range(0, len(pairs), step):
        chunk = pairs[i : i + step]
        yield TaskBatch(
            envelopes=[env for env, _ in chunk],
            futures=[fut for _, fut in chunk],
        )


class BatchCoalescer:
    """Accumulate items; flush when ``max_batch`` is reached or the oldest
    item has waited ``max_delay_s``.

    Thread-safe. Invariant (property-tested): every item passed to
    :meth:`add` appears in exactly one list returned by :meth:`add`,
    :meth:`poll`, or :meth:`flush`, in insertion order — nothing is dropped,
    nothing is duplicated.

    ``max_delay_s == 0`` means "no coalescing window": :meth:`poll` flushes
    whatever is pending immediately.
    """

    def __init__(self, max_batch: int = 64, max_delay_s: float = 0.0):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_delay_s < 0:
            raise ValueError(f"max_delay_s must be >= 0, got {max_delay_s}")
        self.max_batch = max_batch
        self.max_delay_s = max_delay_s
        self._lock = threading.Lock()
        self._pending: List[Any] = []
        self._oldest_at: Optional[float] = None
        self.flushed_batches = 0
        self.flushed_items = 0

    def _drain_locked(self) -> List[Any]:
        out, self._pending = self._pending, []
        self._oldest_at = None
        self.flushed_batches += 1
        self.flushed_items += len(out)
        return out

    def add(self, item: Any, now: Optional[float] = None) -> Optional[List[Any]]:
        """Append ``item``; returns a flushed batch when the add fills the
        frame (flush-on-size), else None."""
        with self._lock:
            if not self._pending:
                self._oldest_at = time.monotonic() if now is None else now
            self._pending.append(item)
            if len(self._pending) >= self.max_batch:
                return self._drain_locked()
            return None

    def poll(self, now: Optional[float] = None) -> Optional[List[Any]]:
        """Flush-on-deadline: returns the pending batch when the oldest item
        has aged past ``max_delay_s`` (or instantly when the window is 0)."""
        with self._lock:
            if not self._pending:
                return None
            if now is None:
                now = time.monotonic()
            if self.max_delay_s > 0 and (now - self._oldest_at) < self.max_delay_s:
                return None
            return self._drain_locked()

    def flush(self) -> List[Any]:
        """Unconditionally drain everything pending (shutdown / failover)."""
        with self._lock:
            if not self._pending:
                return []
            return self._drain_locked()

    def __len__(self) -> int:
        with self._lock:
            return len(self._pending)

    def oldest_age_s(self, now: Optional[float] = None) -> float:
        with self._lock:
            if self._oldest_at is None:
                return 0.0
            return ((time.monotonic() if now is None else now) - self._oldest_at)
