"""Task -> executor scheduling.

The paper's manager "uses a randomized scheduling algorithm to allocate
functions to executors" (§5.3) and names resource-aware scheduling as future
work (§8). We implement randomized scheduling as the paper-faithful baseline
plus three beyond-paper policies measured in the benchmarks — and the §8
future work itself: every policy now runs *after* a capability filter, so a
task only ever reaches an executor hosting a container pool that provides
its required capabilities.

- random: uniform choice among capable executors with capacity.
- round_robin: classic fair rotation.
- least_loaded: pick the executor advertising the most free capacity for
  this task's container type.
- warm_affinity: prefer executors that already hold a warm executable for the
  task's (function, container) — compile-cache locality.
"""
from __future__ import annotations

import random
import threading
from typing import Optional, Sequence

from .futures import TaskEnvelope

POLICIES = ("random", "round_robin", "least_loaded", "warm_affinity")


class Scheduler:
    def __init__(self, policy: str = "random", seed: Optional[int] = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; choose from {POLICIES}")
        self.policy = policy
        self._rng = random.Random(seed)
        self._rr = 0
        self._lock = threading.Lock()

    @staticmethod
    def capable(executors: Sequence, task: TaskEnvelope) -> list:
        """Executors hosting a container pool that can run `task` (the §8
        resource-aware filter — applied before any policy)."""
        return [ex for ex in executors if ex.accepting() and ex.can_run(task)]

    def choose(self, executors: Sequence, task: TaskEnvelope):
        """Pick an executor for `task` (each candidate exposes .accepting(),
        .can_run(env), .free_capacity_for(env), .has_warm(key),
        .executor_id). Returns None if no capable executor has capacity."""
        live = [
            ex for ex in self.capable(executors, task)
            if ex.free_capacity_for(task) > 0
        ]
        if not live:
            return None
        if self.policy == "random":
            return self._rng.choice(live)
        if self.policy == "round_robin":
            with self._lock:
                ex = live[self._rr % len(live)]
                self._rr += 1
            return ex
        if self.policy == "least_loaded":
            return max(live, key=lambda ex: ex.free_capacity_for(task))
        if self.policy == "warm_affinity":
            key = (task.function_id, task.container)
            warm = [ex for ex in live if ex.has_warm(key)]
            pool = warm or live
            return max(pool, key=lambda ex: ex.free_capacity_for(task))
        raise AssertionError(self.policy)
