"""Task -> executor scheduling.

The paper's manager "uses a randomized scheduling algorithm to allocate
functions to executors" (§5.3) and names resource-aware scheduling as future
work (§8). We implement randomized scheduling as the paper-faithful baseline
plus three beyond-paper policies measured in the benchmarks:

- round_robin: classic fair rotation.
- least_loaded: pick the executor with the most free capacity.
- warm_affinity: prefer executors that already hold a warm executable for the
  task's (function, container) — the funcX "future work" of resource-aware
  scheduling, specialized to compile-cache locality.
"""
from __future__ import annotations

import random
import threading
from typing import Optional, Sequence

from .futures import TaskEnvelope

POLICIES = ("random", "round_robin", "least_loaded", "warm_affinity")


class Scheduler:
    def __init__(self, policy: str = "random", seed: Optional[int] = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; choose from {POLICIES}")
        self.policy = policy
        self._rng = random.Random(seed)
        self._rr = 0
        self._lock = threading.Lock()

    def choose(self, executors: Sequence, task: TaskEnvelope):
        """Pick an executor from `executors` (each exposes .free_capacity(),
        .has_warm(key), .executor_id). Returns None if none have capacity."""
        live = [ex for ex in executors if ex.accepting() and ex.free_capacity() > 0]
        if not live:
            return None
        if self.policy == "random":
            return self._rng.choice(live)
        if self.policy == "round_robin":
            with self._lock:
                ex = live[self._rr % len(live)]
                self._rr += 1
            return ex
        if self.policy == "least_loaded":
            return max(live, key=lambda ex: ex.free_capacity())
        if self.policy == "warm_affinity":
            key = (task.function_id, task.container)
            warm = [ex for ex in live if ex.has_warm(key)]
            pool = warm or live
            return max(pool, key=lambda ex: ex.free_capacity())
        raise AssertionError(self.policy)
