"""Data fabric tier: content-addressed object stores + ``DataRef`` indirection.

The paper's pitch is that "computation is mobile, so that ... it can occur
near data", and the funcX journal follow-up (arXiv:2209.11631) lands this as
a first-class tier: pluggable object stores plus data-aware placement. Here
large payload/result leaves stop travelling inline through the Forwarder:

- An :class:`ObjectStore` holds content-hashed blobs (sha256 of the packed
  bytes is the key, so identical data dedupes to one blob). The surface is
  lithops-storage shaped: ``put_object``/``get_object``/``head_object``/
  ``delete_object``/``list_keys`` alias the native ``put``/``get``/... API.
- A :class:`DataRef` (key, size, locations) is a frozen leaf that may appear
  anywhere in a task payload pytree. The serializer packs/unpacks refs as an
  ext type, so a ref-bearing payload is a few hundred bytes on the wire no
  matter how large the data behind it is.
- :func:`spill_payload` replaces big array/bytes leaves with refs (the
  ``FunctionService.spill_threshold`` knob); :func:`resolve_payload`
  materializes them back, preferring a per-endpoint locality cache so a
  dataset shared by many tasks is fetched from the backing store once.

Stores self-register in a process-global registry keyed by ``store_id``
(``mem://...`` / ``fs://<abspath>``) so a ref's ``locations`` tuple is enough
to find bytes from any tier — including a *restarted* fabric: ``get_store``
auto-attaches ``fs://`` stores from their path, which is what keeps journaled
ref-bearing payloads resolvable across a crash (see docs/data.md).
"""
from __future__ import annotations

import hashlib
import os
import tempfile
import threading
import uuid
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

from . import serializer
from .metrics import MetricsRegistry

#: default FunctionService spill threshold (bytes of packed leaf data)
DEFAULT_SPILL_THRESHOLD = 64 * 1024


@dataclass(frozen=True)
class DataRef:
    """A by-reference leaf in a task payload: content key + size + where the
    bytes live. ``locations`` is advisory placement metadata (store ids, best
    first); two refs to the same content with different location lists are
    the *same* data — ``payload_hash`` excludes locations so memoization keys
    don't change when data moves."""

    key: str
    size: int
    locations: Tuple[str, ...] = ()

    def __repr__(self) -> str:  # keep large fan-out logs readable
        return f"DataRef({self.key[:12]}…, {self.size}B, {len(self.locations)} loc)"


class ObjectStore:
    """Content-addressed blob store base: ``put(data) -> key`` where the key
    is the sha256 hex digest of the bytes (idempotent — re-putting identical
    content is a no-op). Subclasses implement the four raw-blob primitives."""

    def __init__(self, store_id: str, register: bool = True,
                 metrics: Optional[MetricsRegistry] = None):
        self.store_id = store_id
        self.metrics: Optional[MetricsRegistry] = metrics
        self._lock = threading.Lock()
        if register:
            register_store(self)

    # -- primitives (override) --------------------------------------------
    def _write(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def _read(self, key: str) -> bytes:
        raise NotImplementedError

    def _has(self, key: str) -> bool:
        raise NotImplementedError

    def _delete(self, key: str) -> None:
        raise NotImplementedError

    def keys(self) -> List[str]:
        raise NotImplementedError

    # -- shared surface ----------------------------------------------------
    @staticmethod
    def content_key(data: bytes) -> str:
        return hashlib.sha256(data).hexdigest()

    def put(self, data: bytes, key: Optional[str] = None) -> str:
        data = bytes(data)
        if key is None:
            key = self.content_key(data)
        with self._lock:
            if not self._has(key):
                self._write(key, data)
                self._account()
        return key

    def get(self, key: str) -> bytes:
        with self._lock:
            if not self._has(key):
                raise KeyError(f"{self.store_id}: no blob {key[:12]}…")
            return self._read(key)

    def delete(self, key: str) -> bool:
        with self._lock:
            if not self._has(key):
                return False
            self._delete(key)
            self._account()
        return True

    def __contains__(self, key: object) -> bool:
        with self._lock:
            return isinstance(key, str) and self._has(key)

    def __len__(self) -> int:
        return len(self.keys())

    def total_bytes(self) -> int:
        raise NotImplementedError

    def bind_metrics(self, metrics: MetricsRegistry) -> None:
        """Adopt a fabric registry: resident-object/byte gauges (labeled by
        store) land in the shared telemetry snapshot."""
        self.metrics = metrics
        with self._lock:
            self._account()

    def _account(self) -> None:
        # called with the lock held, after any mutation
        if self.metrics is None:
            return
        labels = {"store": self.store_id}
        self.metrics.gauge("data.objects", labels).set(len(self.keys()))
        self.metrics.gauge("data.store_bytes", labels).set(self.total_bytes())

    def close(self) -> None:
        """Deregister from the process-global registry (blobs stay put for
        filesystem stores; in-memory blobs die with the object)."""
        deregister_store(self.store_id)

    # -- lithops-storage-shaped aliases ------------------------------------
    def put_object(self, key: str, body: bytes) -> str:
        return self.put(body, key=key)

    def get_object(self, key: str) -> bytes:
        return self.get(key)

    def head_object(self, key: str) -> dict:
        if key not in self:
            raise KeyError(f"{self.store_id}: no blob {key[:12]}…")
        return {"key": key, "size": len(self.get(key))}

    def delete_object(self, key: str) -> bool:
        return self.delete(key)

    def list_keys(self) -> List[str]:
        return self.keys()

    def stats(self) -> dict:
        return {
            "store_id": self.store_id,
            "objects": len(self.keys()),
            "bytes": self.total_bytes(),
        }


class InMemoryStore(ObjectStore):
    """Dict-backed store: the per-endpoint locality cache and the test/bench
    default. Blobs do not survive the process."""

    def __init__(self, store_id: Optional[str] = None, register: bool = True,
                 metrics: Optional[MetricsRegistry] = None):
        self._blobs: Dict[str, bytes] = {}
        super().__init__(
            store_id or f"mem://{uuid.uuid4().hex[:8]}",
            register=register, metrics=metrics,
        )

    def _write(self, key: str, data: bytes) -> None:
        self._blobs[key] = data

    def _read(self, key: str) -> bytes:
        return self._blobs[key]

    def _has(self, key: str) -> bool:
        return key in self._blobs

    def _delete(self, key: str) -> None:
        self._blobs.pop(key, None)

    def keys(self) -> List[str]:
        return list(self._blobs)

    def total_bytes(self) -> int:
        return sum(len(b) for b in self._blobs.values())


class FileSystemStore(ObjectStore):
    """Blob-per-file store rooted at a directory. The ``store_id`` is derived
    from the absolute path (``fs://<abspath>``), so any process — including a
    restarted fabric resuming from a journal — can re-attach the same store
    from a ref's location string alone. Writes are atomic (tmp + rename): a
    crash mid-put never leaves a torn blob behind."""

    def __init__(self, directory: str, register: bool = True,
                 metrics: Optional[MetricsRegistry] = None):
        self.directory = os.path.abspath(directory)
        os.makedirs(self.directory, exist_ok=True)
        super().__init__(
            f"fs://{self.directory}", register=register, metrics=metrics,
        )

    def _path(self, key: str) -> str:
        if os.sep in key or key in (".", ".."):
            raise ValueError(f"invalid blob key {key!r}")
        return os.path.join(self.directory, f"{key}.blob")

    def _write(self, key: str, data: bytes) -> None:
        fd, tmp = tempfile.mkstemp(dir=self.directory, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as fh:
                fh.write(data)
            os.replace(tmp, self._path(key))  # atomic on POSIX
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _read(self, key: str) -> bytes:
        with open(self._path(key), "rb") as fh:
            return fh.read()

    def _has(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def _delete(self, key: str) -> None:
        try:
            os.unlink(self._path(key))
        except FileNotFoundError:
            pass

    def keys(self) -> List[str]:
        return [
            name[: -len(".blob")]
            for name in os.listdir(self.directory)
            if name.endswith(".blob")
        ]

    def total_bytes(self) -> int:
        total = 0
        for name in os.listdir(self.directory):
            if name.endswith(".blob"):
                try:
                    total += os.path.getsize(os.path.join(self.directory, name))
                except OSError:
                    pass
        return total


# -- process-global store registry -----------------------------------------
# A ref's `locations` are store ids; any tier (endpoint dispatch, worker
# safety net, a restarted service resuming from its journal) resolves them
# here. `fs://` stores auto-attach from their path — the durable half of the
# fabric needs no in-memory survivor to find its bytes again.
_STORES: Dict[str, ObjectStore] = {}
_STORES_LOCK = threading.Lock()


def register_store(store: ObjectStore) -> None:
    with _STORES_LOCK:
        _STORES[store.store_id] = store


def deregister_store(store_id: str) -> None:
    with _STORES_LOCK:
        _STORES.pop(store_id, None)


def get_store(store_id: str) -> ObjectStore:
    """Look a store up by id, auto-attaching ``fs://`` stores whose directory
    exists (restart path). Raises KeyError for anything unreachable."""
    with _STORES_LOCK:
        store = _STORES.get(store_id)
    if store is not None:
        return store
    if store_id.startswith("fs://"):
        path = store_id[len("fs://"):]
        if os.path.isdir(path):
            return FileSystemStore(path)
    raise KeyError(f"no reachable object store {store_id!r}")


def reset_store_registry() -> None:
    """Forget every registered store (tests simulating a process restart)."""
    with _STORES_LOCK:
        _STORES.clear()


# -- spill / resolve over payload pytrees -----------------------------------
def _leaf_nbytes(leaf: Any) -> int:
    if isinstance(leaf, np.ndarray):
        return int(leaf.nbytes)
    if isinstance(leaf, (bytes, bytearray)):
        return len(leaf)
    if hasattr(leaf, "__array__") and not isinstance(leaf, (bool, int, float, complex, str)):
        try:
            return int(np.asarray(leaf).nbytes)
        except Exception:
            return 0
    return 0


def spill_payload(
    payload: Any,
    store: ObjectStore,
    threshold: int,
    metrics: Optional[MetricsRegistry] = None,
) -> Tuple[Any, List[DataRef]]:
    """Replace every array/bytes leaf of at least `threshold` bytes with a
    :class:`DataRef` into `store` (blob = the serializer-packed leaf, so a
    resolve is a plain ``unpackb``). Returns the new payload and the full
    ref list it carries — spilled ones plus any refs already present — which
    the Forwarder's transfer estimator consumes. Content-hash keys mean N
    tasks sharing one dataset store one blob."""
    if metrics is None:
        metrics = store.metrics
    refs: List[DataRef] = []

    def walk(obj: Any) -> Any:
        if isinstance(obj, DataRef):
            refs.append(obj)
            return obj
        if isinstance(obj, dict):
            return {k: walk(v) for k, v in obj.items()}
        if isinstance(obj, (list, tuple)):
            out = [walk(v) for v in obj]
            return tuple(out) if isinstance(obj, tuple) else out
        if 0 < threshold <= _leaf_nbytes(obj):
            blob = serializer.packb(obj)
            key = store.put(blob)
            ref = DataRef(key=key, size=len(blob), locations=(store.store_id,))
            refs.append(ref)
            if metrics is not None:
                metrics.counter("data.spilled_leaves").inc()
                metrics.counter("data.bytes_spilled").inc(len(blob))
            return ref
        return obj

    return walk(payload), refs


def scan_refs(payload: Any) -> List[DataRef]:
    """Collect DataRef leaves nested anywhere in a payload pytree."""
    found: List[DataRef] = []

    def walk(obj: Any) -> None:
        if isinstance(obj, DataRef):
            found.append(obj)
        elif isinstance(obj, dict):
            for v in obj.values():
                walk(v)
        elif isinstance(obj, (list, tuple)):
            for v in obj:
                walk(v)

    walk(payload)
    return found


def _fetch_blob(
    ref: DataRef,
    cache: Optional[ObjectStore],
    metrics: Optional[MetricsRegistry],
) -> bytes:
    if cache is not None and ref.key in cache:
        if metrics is not None:
            metrics.counter("data.cache_hits").inc()
        return cache.get(ref.key)
    last_err: Optional[Exception] = None
    for loc in ref.locations:
        try:
            store = get_store(loc)
            blob = store.get(ref.key)
        except KeyError as exc:
            last_err = exc
            continue
        if metrics is not None:
            metrics.counter("data.cache_misses").inc()
            metrics.counter("data.bytes_fetched").inc(len(blob))
        if cache is not None:
            cache.put(blob, key=ref.key)  # locality: next task hits locally
        return blob
    raise KeyError(
        f"DataRef {ref.key[:12]}… unresolvable from locations "
        f"{list(ref.locations)}: {last_err}"
    )


def _fresh_copy(obj: Any) -> Any:
    """Deep-copy the mutable parts of a decoded value so a cached decode can
    be handed to a task without mutations leaking into later tasks. Arrays
    cost one memcpy (which releases the GIL) — far cheaper than re-running
    the msgpack decode path per task."""
    if isinstance(obj, np.ndarray):
        return obj.copy()
    if isinstance(obj, dict):
        return {k: _fresh_copy(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        out = [_fresh_copy(v) for v in obj]
        return tuple(out) if isinstance(obj, tuple) else out
    if isinstance(obj, bytearray):
        return bytearray(obj)
    return obj


def resolve_payload(
    payload: Any,
    cache: Optional[ObjectStore] = None,
    metrics: Optional[MetricsRegistry] = None,
    decoded: Optional[Dict[str, Any]] = None,
) -> Any:
    """Materialize every :class:`DataRef` leaf back into its value,
    preferring `cache` (the per-endpoint locality store) over the ref's
    backing locations. Raises ``KeyError`` when a ref points nowhere
    reachable.

    `decoded` is an optional per-endpoint decoded-value cache (plain dict,
    keyed by blob key): when many tasks at one site reference the same blob,
    the msgpack decode runs once and every resolve hands out a fresh deep
    copy of the cached value — mutation-safe, and the per-task cost drops to
    a memcpy. Concurrent workers may race to populate a key; the duplicate
    decode is harmless and last-write-wins."""

    def walk(obj: Any) -> Any:
        if isinstance(obj, DataRef):
            if metrics is not None:
                metrics.counter("data.resolved_refs").inc()
            if decoded is not None and obj.key in decoded:
                if metrics is not None:
                    metrics.counter("data.decoded_hits").inc()
                return _fresh_copy(decoded[obj.key])
            blob = _fetch_blob(obj, cache, metrics)
            if decoded is not None:
                # cache-bound decode: zero-copy read-only views over the blob
                # bytes — every hand-out below goes through _fresh_copy, whose
                # ndarray.copy() yields a writable array, so the upfront
                # unpack copy was pure waste
                value = serializer.unpackb(blob, writable=False)
                decoded[obj.key] = value
                return _fresh_copy(value)
            return serializer.unpackb(blob)
        if isinstance(obj, dict):
            return {k: walk(v) for k, v in obj.items()}
        if isinstance(obj, (list, tuple)):
            out = [walk(v) for v in obj]
            return tuple(out) if isinstance(obj, tuple) else out
        return obj

    return walk(payload)


def prefetch_refs(
    refs: Iterable[DataRef],
    cache: ObjectStore,
    metrics: Optional[MetricsRegistry] = None,
) -> int:
    """Warm a locality cache with every blob the refs point at (the endpoint
    dispatch path). Only raw blob bytes move — no unpack/repack — and a key
    already resident costs a membership probe, not a read, so the serial
    dispatch loop pays one store read per *new* key and the workers
    materialize values in parallel from the warmed cache."""
    n = 0
    for ref in refs:
        if ref.key in cache:
            if metrics is not None:
                metrics.counter("data.cache_hits").inc()
        else:
            _fetch_blob(ref, cache, metrics)
        n += 1
    return n


def resolve_packed(
    packed: bytes,
    cache: Optional[ObjectStore] = None,
    metrics: Optional[MetricsRegistry] = None,
) -> bytes:
    """Resolve a *packed* ref-bearing payload back to inline packed bytes
    (the endpoint dispatch path: refs materialize at the endpoint, workers
    see plain payloads). The intermediate tree is repacked immediately, never
    handed to user code, so the unpack side rides the zero-copy fast path."""
    return serializer.packb(
        resolve_payload(
            serializer.unpackb(packed, writable=False), cache=cache, metrics=metrics
        )
    )
