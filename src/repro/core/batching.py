"""Batching (paper §5.5, Figs. 8).

Two models, exactly as in the paper:

1. *Executor-side batching*: executors request many tasks per round on behalf
   of their idle workers (implemented in `endpoint.py`'s dispatch loop via
   capacity advertising; this module provides the grouping helper).
2. *User-driven batching*: the caller stacks many input documents into one
   invocation, trading per-request latency for throughput. Helpers here stack
   and unstack array pytrees along a new leading axis.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Any, List, Sequence

import numpy as np

from .futures import TaskEnvelope


def group_by_function(tasks: Sequence[TaskEnvelope]) -> dict:
    """Executor-side grouping: tasks of the same (function, container) can be
    delivered to one executor in a single round."""
    groups: dict = defaultdict(list)
    for t in tasks:
        groups[(t.function_id, t.container)].append(t)
    return dict(groups)


def _tree_map(fn, tree):
    if isinstance(tree, dict):
        return {k: _tree_map(fn, v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        typ = type(tree)
        return typ(_tree_map(fn, v) for v in tree)
    return fn(tree)


def _tree_leaves(tree) -> list:
    out: list = []
    if isinstance(tree, dict):
        for k in sorted(tree, key=repr):
            out.extend(_tree_leaves(tree[k]))
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            out.extend(_tree_leaves(v))
    else:
        out.append(tree)
    return out


def stack_payloads(payloads: Sequence[Any]) -> Any:
    """Stack N structurally-identical payload pytrees along a new axis 0.

    Non-array leaves must be equal across payloads (they become the shared
    value); array leaves are stacked. Raises ValueError on mismatch.
    """
    if not payloads:
        raise ValueError("empty batch")

    def stack_leaf(*leaves):
        if isinstance(leaves[0], np.ndarray) or hasattr(leaves[0], "__array__"):
            return np.stack([np.asarray(x) for x in leaves], axis=0)
        if any(x != leaves[0] for x in leaves[1:]):
            raise ValueError(f"non-array leaves differ across batch: {leaves!r}")
        return leaves[0]

    def rec(*nodes):
        n0 = nodes[0]
        if isinstance(n0, dict):
            keys = set(n0)
            for n in nodes[1:]:
                if set(n) != keys:
                    raise ValueError("payload structures differ (dict keys)")
            return {k: rec(*[n[k] for n in nodes]) for k in n0}
        if isinstance(n0, (list, tuple)):
            ln = len(n0)
            for n in nodes[1:]:
                if len(n) != ln or type(n) is not type(n0):
                    raise ValueError("payload structures differ (sequence)")
            typ = type(n0)
            out = [rec(*[n[i] for n in nodes]) for i in range(ln)]
            return typ(out) if typ is tuple else out
        return stack_leaf(*nodes)

    return rec(*payloads)


def unstack_results(result: Any, n: int) -> List[Any]:
    """Split a stacked result back into per-request results."""

    def get(i):
        def leaf(x):
            if isinstance(x, np.ndarray) or hasattr(x, "__array__"):
                arr = np.asarray(x)
                if arr.ndim >= 1 and arr.shape[0] == n:
                    return arr[i]
            return x

        return _tree_map(leaf, result)

    return [get(i) for i in range(n)]


class MicroBatcher:
    """Accumulates requests until `max_batch` or `max_wait_s`, then flushes.

    Used by the serving engine for continuous batching of decode requests —
    the same flush-on-size / flush-on-deadline policy that the task-flow
    pipeline's :class:`repro.core.interchange.BatchCoalescer` applies between
    tiers, but caller-clocked: the engine loop supplies the oldest-item age
    and drains explicitly, so no internal timestamps are kept.
    """

    def __init__(self, max_batch: int = 8, max_wait_s: float = 0.002):
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._pending: list = []

    def add(self, item) -> None:
        self._pending.append(item)

    def ready(self, oldest_age_s: float) -> bool:
        if not self._pending:
            return False
        return len(self._pending) >= self.max_batch or oldest_age_s >= self.max_wait_s

    def drain(self) -> list:
        out, self._pending = self._pending, []
        return out

    def __len__(self) -> int:
        return len(self._pending)
