"""Result memoization (paper §5.5, Table 3).

funcX memoizes by "hashing the function body and input document and storing a
mapping from hash to computed results". The cache is service-side, LRU-bounded
and thread-safe; it is consulted only when the caller opted in AND the
function is registered deterministic.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Optional, Tuple


class MemoCache:
    def __init__(self, max_entries: int = 4096):
        self.max_entries = max_entries
        self._lock = threading.Lock()
        self._cache: OrderedDict[Tuple[str, str], Any] = OrderedDict()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(function_id: str, payload_digest: str) -> Tuple[str, str]:
        return (function_id, payload_digest)

    def get(self, function_id: str, payload_digest: str) -> Tuple[bool, Optional[Any]]:
        k = self.key(function_id, payload_digest)
        with self._lock:
            if k in self._cache:
                self._cache.move_to_end(k)
                self.hits += 1
                return True, self._cache[k]
            self.misses += 1
            return False, None

    def put(self, function_id: str, payload_digest: str, value: Any) -> None:
        k = self.key(function_id, payload_digest)
        with self._lock:
            self._cache[k] = value
            self._cache.move_to_end(k)
            while len(self._cache) > self.max_entries:
                self._cache.popitem(last=False)

    def invalidate(self, function_id: Optional[str] = None) -> int:
        """Drop entries (all, or those of one function). Returns count dropped."""
        with self._lock:
            if function_id is None:
                n = len(self._cache)
                self._cache.clear()
                return n
            keys = [k for k in self._cache if k[0] == function_id]
            for k in keys:
                del self._cache[k]
            return len(keys)

    def __len__(self) -> int:
        with self._lock:
            return len(self._cache)

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {
                "entries": len(self._cache),
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
            }
