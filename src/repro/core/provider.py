"""Resource providers (paper §5.4: Parsl-provider-style pilot jobs).

funcX provisions compute via Parsl's provider interface (Slurm, PBS, Cobalt,
clouds). Here:

- :class:`LocalThreadProvider` actually provisions (thread-pool "nodes") and
  backs every live endpoint in tests/benchmarks.
- :class:`SlurmProvider` / :class:`TPUPodProvider` generate real submit
  scripts (sbatch / pod-launch) under ``launch/generated/`` — the deliverable
  launch scripts for the production mesh — and only execute them when
  ``submit=True`` (never true in this container).

Scaling policy (elasticity) lives in the endpoint; providers expose
``scale_out``/``scale_in`` blocks like Parsl.
"""
from __future__ import annotations

import abc
import os
import textwrap
from dataclasses import dataclass

from typing import Callable, Dict, List, Optional


@dataclass
class ProviderSpec:
    min_blocks: int = 0
    max_blocks: int = 8
    init_blocks: int = 1
    workers_per_block: int = 4
    # batch-scheduler knobs
    queue: str = "normal"
    walltime: str = "01:00:00"
    account: str = "funcjax"


class Provider(abc.ABC):
    """A block == one node-equivalent (maps to one Executor)."""

    def __init__(self, spec: ProviderSpec):
        self.spec = spec
        self._blocks: Dict[str, object] = {}

    @abc.abstractmethod
    def scale_out(self, n: int) -> List[str]:
        """Provision n blocks; returns block ids."""

    @abc.abstractmethod
    def scale_in(self, block_ids: List[str]) -> None:
        """Release blocks."""

    def release(self, block_ids: List[str]) -> None:
        """Forget blocks without tearing them down — dead-block bookkeeping.
        A watchdog-declared-dead executor may be a false positive (heartbeat
        stall): its threads must stay up to deliver late results, but the
        block must stop counting against ``max_blocks`` so replacements fit."""
        for bid in block_ids:
            self._blocks.pop(bid, None)

    def status(self) -> dict:
        return {"blocks": len(self._blocks), "spec": self.spec}


class LocalThreadProvider(Provider):
    """Blocks are thread-backed Executors created via a factory injected by
    the endpoint (avoids a circular import)."""

    def __init__(self, spec: Optional[ProviderSpec] = None):
        super().__init__(spec or ProviderSpec())
        self._factory: Optional[Callable[[str], object]] = None
        self._counter = 0

    def bind_factory(self, factory: Callable[[str], object]) -> None:
        self._factory = factory

    def scale_out(self, n: int) -> List[str]:
        if self._factory is None:
            raise RuntimeError("provider not bound to an endpoint")
        out = []
        for _ in range(n):
            if len(self._blocks) >= self.spec.max_blocks:
                break
            bid = f"block-{self._counter}"
            self._counter += 1
            self._blocks[bid] = self._factory(bid)
            out.append(bid)
        return out

    def scale_in(self, block_ids: List[str]) -> None:
        for bid in block_ids:
            ex = self._blocks.pop(bid, None)
            if ex is not None and hasattr(ex, "shutdown"):
                ex.shutdown()

    def block(self, block_id: str):
        return self._blocks.get(block_id)


class ScriptProvider(Provider):
    """Base for providers that emit submit scripts instead of local threads."""

    def __init__(self, spec: Optional[ProviderSpec] = None, out_dir: str = "launch/generated",
                 submit: bool = False):
        super().__init__(spec or ProviderSpec())
        self.out_dir = out_dir
        self.submit = submit
        self._counter = 0
        self.generated: List[str] = []

    def _write(self, name: str, content: str) -> str:
        os.makedirs(self.out_dir, exist_ok=True)
        path = os.path.join(self.out_dir, name)
        with open(path, "w") as f:
            f.write(content)
        os.chmod(path, 0o755)
        self.generated.append(path)
        return path

    def scale_in(self, block_ids: List[str]) -> None:
        for bid in block_ids:
            self._blocks.pop(bid, None)


class SlurmProvider(ScriptProvider):
    """Generates sbatch pilot-job scripts that start funcJAX executors."""

    def scale_out(self, n: int) -> List[str]:
        out = []
        for _ in range(n):
            bid = f"slurm-{self._counter}"
            self._counter += 1
            script = textwrap.dedent(
                f"""\
                #!/bin/bash
                #SBATCH --job-name=funcjax-{bid}
                #SBATCH --partition={self.spec.queue}
                #SBATCH --time={self.spec.walltime}
                #SBATCH --account={self.spec.account}
                #SBATCH --nodes=1
                #SBATCH --ntasks-per-node=1

                # funcJAX pilot job: start one executor block that connects
                # back to the endpoint manager (capacity advertising + heartbeats).
                export PYTHONPATH=src
                python -m repro.launch.executor_block \\
                    --block-id {bid} \\
                    --workers {self.spec.workers_per_block} \\
                    --manager-url "$FUNCJAX_MANAGER_URL"
                """
            )
            path = self._write(f"{bid}.sbatch", script)
            self._blocks[bid] = path
            out.append(bid)
            if self.submit:  # pragma: no cover - no scheduler in this container
                os.system(f"sbatch {path}")
        return out


class TPUPodProvider(ScriptProvider):
    """Generates pod-slice launch scripts (gcloud/xpk style) for the
    production mesh: one process per host, 4 chips per host, v5e-256 slices."""

    def __init__(self, spec: Optional[ProviderSpec] = None, out_dir: str = "launch/generated",
                 submit: bool = False, pod_slices: int = 2, chips_per_slice: int = 256):
        super().__init__(spec, out_dir, submit)
        self.pod_slices = pod_slices
        self.chips_per_slice = chips_per_slice

    def scale_out(self, n: int) -> List[str]:
        out = []
        for _ in range(n):
            bid = f"pod-{self._counter}"
            self._counter += 1
            hosts = self.chips_per_slice // 4
            script = textwrap.dedent(
                f"""\
                #!/bin/bash
                # funcJAX pod-slice launcher ({self.chips_per_slice} chips, {hosts} hosts).
                # Every host runs the same binary; jax.distributed.initialize()
                # derives coordinator/rank from the TPU environment.
                set -euo pipefail
                SLICE_ID={bid}
                gcloud compute tpus tpu-vm ssh funcjax-$SLICE_ID --worker=all --command '
                  export PYTHONPATH=src
                  export FUNCJAX_NUM_SLICES={self.pod_slices}
                  python -m repro.launch.train \\
                      --arch "$FUNCJAX_ARCH" --shape "$FUNCJAX_SHAPE" \\
                      --multi-pod --slice-id '$SLICE_ID'
                '
                """
            )
            path = self._write(f"{bid}.sh", script)
            self._blocks[bid] = path
            out.append(bid)
        return out
