"""Data pipeline with prefetch (the paper's §5.5 prefetching applied to the
input path): a background thread keeps `depth` ready-to-consume batches in a
queue, overlapping host-side batch construction / device transfer with step
compute. Synthetic deterministic token streams back the examples, tests and
benchmarks (no external datasets in this container).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np

from ..configs.base import ModelConfig


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, step: int) -> Dict[str, Any]:
    """Deterministic batch for `step` (restart-reproducible)."""
    rng = np.random.default_rng(1234 + step)
    if cfg.family == "vlm":
        return {
            "tokens": rng.integers(0, cfg.vocab, (batch, seq - cfg.n_patches), dtype=np.int32),
            "patches": rng.standard_normal((batch, cfg.n_patches, cfg.d_model)).astype(np.float32),
        }
    out = {"tokens": rng.integers(0, cfg.vocab, (batch, seq), dtype=np.int32)}
    if cfg.family == "encdec":
        out["frames"] = rng.standard_normal((batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)
    return out


def token_stream(cfg: ModelConfig, batch: int, seq: int, start_step: int = 0) -> Iterator[Dict]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, batch, seq, step)
        step += 1


class Prefetcher:
    """Wraps an iterator; a worker thread keeps up to `depth` items ready.
    `transform` (e.g. jax.device_put with batch shardings) runs on the worker
    thread so transfer overlaps compute."""

    def __init__(self, it: Iterator, depth: int = 2,
                 transform: Optional[Callable[[Any], Any]] = None):
        self.depth = depth
        self._it = it
        self._transform = transform
        self._q: "queue.Queue" = queue.Queue(maxsize=max(depth, 1))
        self._stop = threading.Event()
        self._exc: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                if self._transform is not None:
                    item = self._transform(item)
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # noqa: BLE001
            self._exc = e
        finally:
            self._q.put(_SENTINEL)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is _SENTINEL:
            if self._exc is not None:
                raise self._exc
            raise StopIteration
        return item

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


class _Sentinel:
    pass


_SENTINEL = _Sentinel()
