"""Model/config dataclasses shared by all assigned architectures."""
from __future__ import annotations

from dataclasses import dataclass, replace

from typing import Optional



@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    norm_topk_prob: bool = True


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False           # Qwen3-style per-head RMSNorm on q,k
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (whisper): encoder depth and fixed frame count (stub frontend)
    n_enc_layers: int = 0
    enc_seq: int = 0
    # vlm: number of prepended patch embeddings (stub frontend)
    n_patches: int = 0
    # hybrid (zamba2): one shared attention block applied every k mamba layers
    shared_attn_every: int = 0
    # numerics / compile shape
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots | dots_no_batch
    scan_layers: bool = True
    sequence_parallel: bool = False
    # §Perf hillclimb levers (baseline values reproduce the paper-faithful run)
    moe_combine: str = "scatter"    # scatter | gather (token-side gather combine)
    moe_impl: str = "global"        # global (XLA SPMD partitions the dispatch)
    #                                 | local (shard_map: per-shard routing,
    #                                 local expert compute, one psum — zero
    #                                 dispatch collectives)
    attn_seq_shard: bool = False    # context-parallel attention: shard q over
    #                                 seq on `model` when heads aren't divisible
    pure_dp: bool = False           # ZeRO-3 layout: batch shards over BOTH mesh
    #                                 axes (viable when global_batch >= chips);
    #                                 params stay 2D-sharded at rest and are
    #                                 all-gathered per layer — no TP all-reduces
    microbatches: int = 1           # grad accumulation: divides activation
    #                                 memory by M at the cost of M serial passes
    # serving
    max_decode_len: int = 0         # 0 -> shape-driven

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    # -- parameter count (analytical; used for MODEL_FLOPS = 6·N·D) --------
    def param_count(self, active_only: bool = False) -> int:
        D, V, L = self.d_model, self.vocab, self.n_layers
        total = V * D * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                qk = m.qk_nope_dim + m.qk_rope_dim
                return (
                    D * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
                    + D * (m.kv_lora_rank + m.qk_rope_dim)
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * D
                )
            return D * self.hd * (2 * self.n_heads + 2 * self.n_kv_heads)

        def mlp_params(dff: int) -> int:
            return 3 * D * dff  # SwiGLU

        def moe_params(active: bool) -> int:
            m = self.moe
            e = m.top_k if active else m.n_experts
            p = D * m.n_experts  # router
            p += e * 3 * D * m.d_ff_expert
            if m.n_shared_experts:
                p += 3 * D * m.d_ff_shared + D  # shared experts + gate
            return p

        def ssm_params() -> int:
            s = self.ssm
            d_in = s.d_inner(D)
            nh = s.n_heads(D)
            conv_dim = d_in + 2 * s.n_groups * s.d_state
            return (
                D * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
                + conv_dim * s.conv_kernel
                + 3 * nh  # A_log, D, dt_bias
                + d_in  # gated norm
                + d_in * D
            )

        if self.family in ("dense", "vlm"):
            total += L * (attn_params() + mlp_params(self.d_ff) + 2 * D)
            if self.family == "vlm":
                total += D * D  # patch projection stub
        elif self.family == "moe":
            total += L * (attn_params() + moe_params(active_only) + 2 * D)
        elif self.family == "ssm":
            total += L * (ssm_params() + D)
        elif self.family == "hybrid":
            total += L * (ssm_params() + D)
            n_shared_applications = L // max(self.shared_attn_every, 1)
            shared = attn_params() + mlp_params(self.d_ff) + 2 * D
            total += shared  # parameters stored once
            if active_only:
                total += shared * max(n_shared_applications - 1, 0)  # re-used compute
        elif self.family == "encdec":
            total += self.n_enc_layers * (attn_params() + mlp_params(self.d_ff) + 2 * D)
            # decoder: self-attn + cross-attn + mlp
            total += L * (2 * attn_params() + mlp_params(self.d_ff) + 3 * D)
        else:
            raise ValueError(self.family)
        return int(total)


# architecture registry, populated by configs/__init__.py
ARCHS: dict = {}


def register_arch(cfg: ModelConfig, reduced: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = {"full": cfg, "reduced": reduced}
    return cfg
