"""Assigned-architecture configs (10 archs) + shape sets.

``get_config(arch_id)`` returns the exact published config;
``get_reduced(arch_id)`` the smoke-test reduction of the same family.
"""
from .base import ARCHS, MLAConfig, ModelConfig, MoEConfig, SSMConfig  # noqa: F401

# importing each module populates ARCHS
from . import (  # noqa: F401,E402
    deepseek_67b,
    internvl2_26b,
    mamba2_2_7b,
    minicpm3_4b,
    qwen1_5_0_5b,
    qwen2_0_5b,
    qwen2_moe_a2_7b,
    qwen3_moe_235b,
    whisper_small,
    zamba2_2_7b,
)
from .shapes import SHAPES, ShapeSpec, all_cells, cell_applicable  # noqa: F401,E402

ARCH_IDS = tuple(sorted(ARCHS))


def get_config(arch_id: str) -> ModelConfig:
    try:
        return ARCHS[arch_id]["full"]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}") from None


def get_reduced(arch_id: str) -> ModelConfig:
    try:
        return ARCHS[arch_id]["reduced"]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}") from None
