"""qwen2-0.5b [dense] — arXiv:2407.10671.

24L, d_model=896, 14H (GQA kv=2, head_dim=64), d_ff=4864, vocab=151936,
QKV bias, tied embeddings. 14 heads % 16 != 0 -> attention projections
replicate over `model` on the production mesh (d_ff and vocab still shard).
"""
from .base import ModelConfig, register_arch

FULL = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

REDUCED = ModelConfig(
    name="qwen2-0.5b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    qkv_bias=True,
    tie_embeddings=True,
)

register_arch(FULL, REDUCED)
