"""zamba2-2.7b [hybrid] — arXiv:2411.15242.

54 Mamba2 layers, d_model=2560, ssm_state=64; one shared attention(+MLP)
block (32H, kv=32) applied every 6 Mamba layers with re-used parameters
(Zamba2's shared-block scheme, simplified to a single shared block).
Runs long_500k: SSM state is O(1) in sequence length and the shared
attention decode is a single-query pass.
"""
from .base import ModelConfig, SSMConfig, register_arch

FULL = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_kernel=4, chunk=256),
    shared_attn_every=6,
)

REDUCED = ModelConfig(
    name="zamba2-2.7b-reduced",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_kernel=4, chunk=16),
    shared_attn_every=2,
)

register_arch(FULL, REDUCED)
