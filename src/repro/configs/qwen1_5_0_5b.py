"""qwen1.5-0.5b [dense] — hf:Qwen/Qwen1.5-0.5B.

24L, d_model=1024, 16H (kv=16, head_dim=64), d_ff=2816, vocab=151936,
QKV bias, tied embeddings. Fully TP-shardable on the 16-way model axis.
"""
from .base import ModelConfig, register_arch

FULL = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="qwen1.5-0.5b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab=256,
    qkv_bias=True,
    tie_embeddings=True,
)

register_arch(FULL, REDUCED)
