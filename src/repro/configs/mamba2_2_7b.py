"""mamba2-2.7b [ssm] — arXiv:2405.21060 (SSD / state-space duality).

64L, d_model=2560 (attention-free), d_inner=5120, head_dim=64 -> 80 SSD
heads, state N=128, conv kernel 4, vocab=50280. Runs long_500k: decode
state is O(1) in sequence length.
"""
from .base import ModelConfig, SSMConfig, register_arch

FULL = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4, chunk=256),
)

REDUCED = ModelConfig(
    name="mamba2-2.7b-reduced",
    family="ssm",
    n_layers=3,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=256,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_kernel=4, chunk=16),
)

register_arch(FULL, REDUCED)
