"""internvl2-26b [vlm] — arXiv:2404.16821 (InternViT-6B + InternLM2-20B).

LM backbone: 48L, d_model=6144, 48H (kv=8, head_dim=128), d_ff=16384,
vocab=92553. The InternViT frontend is a STUB: input_specs() provides 256
precomputed patch embeddings (B, 256, 6144) prepended to the text tokens.
"""
from .base import ModelConfig, register_arch

FULL = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92553,
    n_patches=256,
)

REDUCED = ModelConfig(
    name="internvl2-26b-reduced",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    n_patches=8,
)

register_arch(FULL, REDUCED)
