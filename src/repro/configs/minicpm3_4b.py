"""minicpm3-4b [dense, MLA] — hf:openbmb/MiniCPM3-4B.

62L, d_model=2560, 40H, d_ff=6400, vocab=73448, Multi-head Latent
Attention: q_lora=768, kv_lora=256, qk_nope=64 + qk_rope=32 per head,
v_head=64. Decode caches the compressed latent (kv_lora + rope per token).
"""
from .base import MLAConfig, ModelConfig, register_arch

FULL = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=96,  # qk_nope + qk_rope
    d_ff=6400,
    vocab=73448,
    mla=MLAConfig(
        q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64
    ),
)

REDUCED = ModelConfig(
    name="minicpm3-4b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=24,
    d_ff=128,
    vocab=256,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
)

register_arch(FULL, REDUCED)
