"""whisper-small [audio enc-dec] — arXiv:2212.04356.

12L enc + 12L dec, d_model=768, 12H (kv=12), d_ff=3072, vocab=51865.
Conv frontend is a STUB: input_specs() provides precomputed 1500-frame
embeddings (B, 1500, 768); assigned shapes apply to the decoder sequence.
"""
from .base import ModelConfig, register_arch

FULL = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    n_enc_layers=12,
    enc_seq=1500,
    rope_theta=0.0,  # whisper uses sinusoidal absolute positions, not RoPE
)

REDUCED = ModelConfig(
    name="whisper-small-reduced",
    family="encdec",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    n_enc_layers=2,
    enc_seq=24,
    rope_theta=0.0,
)

register_arch(FULL, REDUCED)
