"""Assigned input shapes (LM-family: seq_len x global_batch).

``decode_*`` / ``long_*`` lower ``decode_step`` (one new token against a KV
cache of seq_len); ``prefill_32k`` lowers ``prefill_step``; ``train_4k``
lowers ``train_step``. ``long_500k`` is defined only for sub-quadratic archs
(ssm / hybrid here); full-attention archs record the skip.
"""
from __future__ import annotations

from dataclasses import dataclass

from .base import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-skipped). Encoder-only archs would skip decode
    shapes, but none are assigned; whisper is enc-dec and decodes."""
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, "long_500k requires sub-quadratic attention (ssm/hybrid only)"
    return True, ""


def all_cells() -> list:
    from . import ARCHS

    cells = []
    for arch in sorted(ARCHS):
        cfg = ARCHS[arch]["full"]
        for shape in SHAPES.values():
            ok, reason = cell_applicable(cfg, shape)
            cells.append((arch, shape.name, ok, reason))
    return cells
