"""qwen3-moe-235b-a22b [moe] — hf:Qwen/Qwen3-235B-A22B (family per Qwen3-30B-A3B).

94L, d_model=4096, 64H (kv=4, head_dim=128), MoE 128 experts top-8 with
expert d_ff=1536, vocab=151936, per-head q/k RMSNorm (Qwen3 style).
"""
from .base import ModelConfig, MoEConfig, register_arch

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,  # expert d_ff (Qwen3-MoE has no dense MLP path)
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536, capacity_factor=1.25),
)

REDUCED = ModelConfig(
    name="qwen3-moe-235b-a22b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab=256,
    qk_norm=True,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96, capacity_factor=1.5),
)

register_arch(FULL, REDUCED)
