"""qwen2-moe-a2.7b [moe] — hf:Qwen/Qwen1.5-MoE-A2.7B.

24L, d_model=2048, 16H (kv=16), 60 routed experts top-4 (d_ff=1408) plus
4 shared experts (merged shared d_ff=5632) with a sigmoid shared-expert
gate, vocab=151936, QKV bias. 60 % 16 != 0 -> the partitioner falls back
to TP-MoE (expert d_ff sharded over `model`, experts replicated).
"""
from .base import ModelConfig, MoEConfig, register_arch

FULL = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    qkv_bias=True,
    moe=MoEConfig(
        n_experts=60,
        top_k=4,
        d_ff_expert=1408,
        n_shared_experts=4,
        d_ff_shared=5632,
        capacity_factor=1.25,
        norm_topk_prob=False,
    ),
)

REDUCED = ModelConfig(
    name="qwen2-moe-a2.7b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab=256,
    qkv_bias=True,
    moe=MoEConfig(
        n_experts=6, top_k=2, d_ff_expert=64, n_shared_experts=2, d_ff_shared=128,
        capacity_factor=1.5, norm_topk_prob=False,
    ),
)

register_arch(FULL, REDUCED)
