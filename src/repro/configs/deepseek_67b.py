"""deepseek-67b [dense] — arXiv:2401.02954 (llama-arch).

95L, d_model=8192, 64H (GQA kv=8, head_dim=128), d_ff=22016, vocab=102400.
The flagship dense cell of the assignment.
"""
from .base import ModelConfig, register_arch

FULL = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab=102400,
)

REDUCED = ModelConfig(
    name="deepseek-67b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab=256,
)

register_arch(FULL, REDUCED)
