"""Step builders: the registered "functions" of serverless supercomputing.

``build_train_step`` / ``build_prefill_step`` / ``build_decode_step`` produce
jittable callables plus their in/out shardings resolved from logical axis
specs — exactly what the dry-run lowers and what the FaaS endpoint registers
and dispatches.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..configs.shapes import ShapeSpec
from ..models.model import Model
from ..sharding import partition
from . import optimizer as opt


def batch_avals(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell (no
    allocation — the multi-pod dry-run contract)."""
    B, S = shape.global_batch, shape.seq_len
    f = jax.ShapeDtypeStruct
    dt = jnp.dtype(cfg.dtype)
    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            return {
                "tokens": f((B, S - cfg.n_patches), jnp.int32),
                "patches": f((B, cfg.n_patches, cfg.d_model), dt),
            }
        if cfg.family == "encdec":
            return {
                "tokens": f((B, S), jnp.int32),
                "frames": f((B, cfg.enc_seq, cfg.d_model), dt),
            }
        return {"tokens": f((B, S), jnp.int32)}
    if shape.kind == "decode":
        return {"token": f((B, 1), jnp.int32)}
    raise ValueError(shape.kind)


def batch_logical_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, tuple]:
    if shape.kind in ("train", "prefill"):
        out = {"tokens": ("batch", "seq")}
        if cfg.family == "vlm":
            out["patches"] = ("batch", "seq", None)
        if cfg.family == "encdec":
            out["frames"] = ("batch", "seq", None)
        return out
    return {"token": ("batch", None)}


@dataclass
class BuiltStep:
    fn: Any                    # callable(params/state..., batch...) -> outputs
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple
    abstract_args: tuple       # avals for .lower()


def _shardings(logical_tree, aval_tree, mesh: Mesh, rules=None):
    return partition.named_shardings(logical_tree, aval_tree, mesh, rules=rules)


def build_train_step(
    model: Model,
    ocfg: opt.OptimizerConfig,
    mesh: Optional[Mesh] = None,
    shape: Optional[ShapeSpec] = None,
) -> BuiltStep:
    cfg = model.cfg
    M = max(cfg.microbatches, 1)

    grad_shardings = None
    if mesh is not None:
        rules = partition.rules_for(cfg)
        p_specs = model.specs()
        p_avals = model.abstract_params()
        param_sh = _shardings(p_specs, p_avals, mesh, rules)
        grad_shardings = param_sh

    def _grads(params, batch):
        """value_and_grad (+ optional microbatch accumulation). Grads are cast
        to grad_dtype and pinned to the param sharding IMMEDIATELY — without
        the constraint XLA all-reduces fp32 wgrads and slices afterwards
        (measured: 2x the bytes on every train cell; see EXPERIMENTS.md)."""
        gdt = jnp.dtype(ocfg.grad_dtype)

        def one(params, mb):
            (loss, metrics), g = jax.value_and_grad(model.loss, has_aux=True)(params, mb)
            g = jax.tree.map(lambda x: x.astype(gdt), g)
            if grad_shardings is not None:
                g = jax.tree.map(jax.lax.with_sharding_constraint, g, grad_shardings)
            return loss, metrics, g

        if M == 1:
            return one(params, batch)

        split = jax.tree.map(lambda x: x.reshape(M, x.shape[0] // M, *x.shape[1:]), batch)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
        if grad_shardings is not None:
            g0 = jax.tree.map(jax.lax.with_sharding_constraint, g0, grad_shardings)

        def body(carry, mb):
            gacc, lacc, ceacc, auxacc = carry
            loss, metrics, g = one(params, mb)
            gacc = jax.tree.map(lambda a, b: a + b, gacc, g)
            return (gacc, lacc + loss, ceacc + metrics["ce"], auxacc + metrics["aux"]), None

        (g, lsum, cesum, auxsum), _ = jax.lax.scan(
            body, (g0, jnp.float32(0), jnp.float32(0), jnp.float32(0)), split
        )
        g = jax.tree.map(lambda x: (x / M).astype(gdt), g)
        metrics = {"loss": lsum / M, "ce": cesum / M, "aux": auxsum / M}
        return lsum / M, metrics, g

    def train_step(params, opt_state, batch):
        loss, metrics, grads = _grads(params, batch)
        param_dtypes = jax.tree.map(lambda p: p.dtype, params)
        new_params, new_state = opt.apply_updates(grads, opt_state, ocfg, param_dtypes)
        metrics = dict(metrics, grad_norm=opt.global_norm(grads),
                       lr=opt.schedule(ocfg, new_state["step"]))
        return new_params, new_state, metrics

    if mesh is None:
        return BuiltStep(train_step, None, None, (0, 1), ())

    s_specs = opt.state_specs(p_specs)
    s_avals = jax.eval_shape(lambda p: opt.init_state(p, ocfg), p_avals)
    b_avals = batch_avals(cfg, shape)
    b_specs = batch_logical_specs(cfg, shape)

    in_sh = (
        param_sh,
        _shardings(s_specs, s_avals, mesh, rules),
        _shardings(b_specs, b_avals, mesh, rules),
    )
    metric_sh = NamedSharding(mesh, P())
    out_sh = (in_sh[0], in_sh[1], jax.tree.map(lambda _: metric_sh,
              {"ce": 0, "aux": 0, "loss": 0, "grad_norm": 0, "lr": 0}))
    return BuiltStep(train_step, in_sh, out_sh, (0, 1), (p_avals, s_avals, b_avals))


def build_prefill_step(model: Model, mesh: Optional[Mesh] = None,
                       shape: Optional[ShapeSpec] = None) -> BuiltStep:
    cfg = model.cfg

    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, cache

    if mesh is None:
        return BuiltStep(prefill_step, None, None, (), ())

    rules = partition.rules_for(cfg)
    p_specs = model.specs()
    p_avals = model.abstract_params()
    b_avals = batch_avals(cfg, shape)
    b_specs = batch_logical_specs(cfg, shape)
    in_sh = (_shardings(p_specs, p_avals, mesh, rules),
             _shardings(b_specs, b_avals, mesh, rules))

    cache_avals, cache_specs = _cache_avals_specs(model, shape, mesh)
    B = shape.global_batch
    tok_aval = jax.ShapeDtypeStruct((B,), jnp.int32)
    next_tok_sh = _shardings({"t": ("batch",)}, {"t": tok_aval}, mesh, rules)["t"]
    logits_aval = jax.ShapeDtypeStruct((B, cfg.vocab), jnp.float32)
    logits_sh = _shardings({"l": ("batch", "vocab")}, {"l": logits_aval}, mesh, rules)["l"]
    out_sh = (
        next_tok_sh,
        logits_sh,
        _shardings(cache_specs, cache_avals, mesh, rules),
    )
    return BuiltStep(prefill_step, in_sh, out_sh, (), (p_avals, b_avals))


def _cache_avals_specs(model: Model, shape: ShapeSpec, mesh: Mesh):
    captured = {}

    def f():
        c, s = model.init_cache(shape.global_batch, shape.seq_len)
        captured["s"] = s
        return c

    with partition.use_mesh(mesh, rules=partition.rules_for(model.cfg)):
        avals = jax.eval_shape(f)
    return avals, captured["s"]


def build_decode_step(model: Model, mesh: Optional[Mesh] = None,
                      shape: Optional[ShapeSpec] = None) -> BuiltStep:
    cfg = model.cfg

    def decode_step(params, token, cache, pos):
        logits, new_cache = model.decode_step(params, token, cache, pos)
        next_token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return next_token, new_cache

    if mesh is None:
        return BuiltStep(decode_step, None, None, (2,), ())

    rules = partition.rules_for(cfg)
    p_specs = model.specs()
    p_avals = model.abstract_params()
    b_avals = batch_avals(cfg, shape)
    cache_avals, cache_specs = _cache_avals_specs(model, shape, mesh)
    tok_sh = _shardings(batch_logical_specs(cfg, shape), b_avals, mesh, rules)["token"]
    cache_sh = _shardings(cache_specs, cache_avals, mesh, rules)
    in_sh = (_shardings(p_specs, p_avals, mesh), tok_sh, cache_sh, NamedSharding(mesh, P()))
    out_sh = (tok_sh, cache_sh)
    pos_aval = jax.ShapeDtypeStruct((), jnp.int32)
    return BuiltStep(
        decode_step, in_sh, out_sh, (2,),
        (p_avals, b_avals["token"], cache_avals, pos_aval),
    )
