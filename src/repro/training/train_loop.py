"""Training driver: steps are FaaS functions ("serverless supercomputing").

The trainer registers ``train_step`` on a funcJAX endpoint and submits each
step as a function invocation — warm executable cache makes step 2+ cheap,
the endpoint watchdog re-executes steps lost to executor failure, and the
checkpointer bounds lost work on controller failure. This is the paper's
model applied to training: the "function" happens to span a pod.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from typing import Dict, List, Optional



import jax

from ..checkpoint.checkpointer import Checkpointer
from ..core.service import FunctionService
from ..data.pipeline import Prefetcher, token_stream
from ..models.model import Model
from . import optimizer as opt
from .steps import build_train_step


@dataclass
class TrainConfig:
    steps: int = 100
    batch: int = 8
    seq: int = 128
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    prefetch_depth: int = 2
    log_every: int = 10
    resume: bool = True


class Trainer:
    def __init__(
        self,
        model: Model,
        ocfg: opt.OptimizerConfig,
        tcfg: TrainConfig,
        service: Optional[FunctionService] = None,
        endpoint_id: Optional[str] = None,
        seed: int = 0,
    ):
        self.model = model
        self.ocfg = ocfg
        self.tcfg = tcfg
        self.service = service
        self.endpoint_id = endpoint_id
        self.history: List[Dict[str, float]] = []

        built = build_train_step(model, ocfg)
        self._step_fn = jax.jit(built.fn, donate_argnums=built.donate_argnums)

        key = jax.random.PRNGKey(seed)
        self.params = model.init(key)
        self.opt_state = opt.init_state(self.params, ocfg)
        self.step = 0

        self.ckpt = Checkpointer(tcfg.ckpt_dir) if tcfg.ckpt_dir else None
        if self.ckpt and tcfg.resume and self.ckpt.latest_step() is not None:
            self.step, state = self.ckpt.restore(
                {"params": self.params, "opt": self.opt_state}
            )
            self.params, self.opt_state = state["params"], state["opt"]

        self._fid = None
        if service is not None:
            # pass_through + unserialized results: device arrays never hit the
            # wire; the FaaS layer provides routing, warming, retry, telemetry.
            def train_step_function(doc):
                return self._step_fn(doc["params"], doc["opt"], doc["batch"])

            self._fid = service.register_function(
                train_step_function,
                name=f"train_step/{model.cfg.name}",
                pass_through=True,
                serialize_result=False,
                static=repr((model.cfg, ocfg)),
            )

    def _run_one(self, batch) -> Dict[str, float]:
        doc = {"params": self.params, "opt": self.opt_state, "batch": batch}
        if self.service is not None:
            fut = self.service.run(self._fid, doc, endpoint_id=self.endpoint_id,
                                   max_retries=2)
            self.params, self.opt_state, metrics = fut.result(timeout=600)
        else:
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, doc["batch"]
            )
        return {k: float(v) for k, v in metrics.items()}

    def run(self) -> List[Dict[str, float]]:
        cfg, t = self.model.cfg, self.tcfg
        stream = token_stream(cfg, t.batch, t.seq, start_step=self.step)
        pf = Prefetcher(stream, depth=t.prefetch_depth)
        t0 = time.monotonic()
        try:
            while self.step < t.steps:
                batch = next(pf)
                metrics = self._run_one(batch)
                self.step += 1
                metrics["step"] = self.step
                metrics["wall_s"] = time.monotonic() - t0
                self.history.append(metrics)
                if t.log_every and self.step % t.log_every == 0:
                    print(
                        f"step {self.step:5d} loss {metrics['loss']:.4f} "
                        f"grad_norm {metrics['grad_norm']:.3f} lr {metrics['lr']:.2e}",
                        flush=True,
                    )
                if self.ckpt and self.step % t.ckpt_every == 0:
                    self.ckpt.save(self.step, {"params": self.params, "opt": self.opt_state})
        finally:
            pf.close()
            if self.ckpt:
                self.ckpt.save(self.step, {"params": self.params, "opt": self.opt_state},
                               blocking=True)
        return self.history
