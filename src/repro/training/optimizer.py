"""AdamW with fp32 master weights + moments, fully sharded (ZeRO-3-like:
optimizer state inherits the 2D FSDPxTP param sharding), global-norm clip,
warmup+cosine schedule, and bf16 gradient reduction ("compression": the
cross-data-axis reduce runs at half the bytes of an fp32 baseline; an
optional stochastic-rounding cast guards the master update).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_dtype: str = "bfloat16"       # reduction precision ("compression")
    moments_dtype: str = "float32"     # bf16 moments halve optimizer-state HBM
    stochastic_rounding: bool = False  # SR when casting update back to bf16


def schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    floor = cfg.min_lr_ratio
    return cfg.lr * warm * (floor + (1 - floor) * cos)


def init_state(params, cfg: "OptimizerConfig" = None) -> Dict[str, Any]:
    # force a fresh buffer: for fp32 params .astype is a no-op alias, and an
    # aliased master would be double-donated by train_step's donate_argnums
    f32 = lambda p: jnp.array(p, jnp.float32, copy=True)
    mdt = jnp.dtype(cfg.moments_dtype) if cfg is not None else jnp.float32
    return {
        "master": jax.tree.map(f32, params),
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_specs(param_specs) -> Dict[str, Any]:
    """Optimizer-state logical specs mirror the params'."""
    is_leaf = lambda x: isinstance(x, tuple)
    same = lambda tree: jax.tree.map(lambda s: s, tree, is_leaf=is_leaf)
    return {
        "master": same(param_specs),
        "mu": same(param_specs),
        "nu": same(param_specs),
        "step": (),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def _sr_cast(x: jnp.ndarray, dtype, key) -> jnp.ndarray:
    """Stochastic rounding to `dtype` (guards repeated-cast bias)."""
    if x.dtype == dtype:
        return x
    down = x.astype(dtype)
    up = jnp.nextafter(down.astype(jnp.float32), jnp.inf).astype(dtype)
    span = (up.astype(jnp.float32) - down.astype(jnp.float32))
    frac = jnp.where(span > 0, (x - down.astype(jnp.float32)) / jnp.where(span > 0, span, 1), 0)
    u = jax.random.uniform(key, x.shape)
    return jnp.where(u < frac, up, down)


def apply_updates(
    grads,
    state: Dict[str, Any],
    cfg: OptimizerConfig,
    param_dtypes,
    sr_key: Optional[jnp.ndarray] = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Returns (new compute-dtype params, new state)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moments_dtype)

    def upd(g, m, v, w):
        # fp32 cast + clip PER LEAF: a tree-wide cast would materialize a
        # full fp32 gradient copy and set the whole step's memory peak
        # (3.7 GiB/device on the 235B MoE cell — see EXPERIMENTS.md §Perf)
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        w = w - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * w)
        return m32.astype(mdt), v32.astype(mdt), w

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["mu"])
    flat_v = jax.tree.leaves(state["nu"])
    flat_w = jax.tree.leaves(state["master"])
    out_m, out_v, out_w = [], [], []
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        m2, v2, w2 = upd(g, m, v, w)
        out_m.append(m2)
        out_v.append(v2)
        out_w.append(w2)

    new_state = {
        "master": jax.tree.unflatten(treedef, out_w),
        "mu": jax.tree.unflatten(treedef, out_m),
        "nu": jax.tree.unflatten(treedef, out_v),
        "step": step,
    }

    dtypes = jax.tree.leaves(param_dtypes)
    if cfg.stochastic_rounding and sr_key is not None:
        keys = jax.random.split(sr_key, len(out_w))
        new_params = [
            _sr_cast(w, dt, k) for w, dt, k in zip(out_w, dtypes, keys)
        ]
    else:
        new_params = [w.astype(dt) for w, dt in zip(out_w, dtypes)]
    return jax.tree.unflatten(treedef, new_params), new_state
