"""Continuous-batching serve engine on top of the FaaS endpoint.

Model steps are *registered functions* (pass-through payloads: device-resident
arrays never serialize); the engine implements the DLHub/ML-inference case
study of the paper (§7) with the paper's optimizations applied automatically:
user-driven batching (decode steps run over all active slots at once),
executable warming (prefill/decode jits stay hot), and memoization left to
the service layer for deterministic requests.
"""
from __future__ import annotations

import threading
import time
import uuid
from dataclasses import dataclass, field
from typing import List, Optional



import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.metrics import MetricsRegistry
from ..models.model import Model
from . import kv_cache


@dataclass
class Request:
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never stops early
    request_id: str = field(default_factory=lambda: uuid.uuid4().hex[:8])
    # outputs
    tokens: List[int] = field(default_factory=list)
    done: threading.Event = field(default_factory=threading.Event)
    submitted: float = field(default_factory=time.monotonic)
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None


class ServeEngine:
    """Slot-based continuous batching: `max_batch` concurrent sequences share
    one stacked cache; new requests prefill into free slots while existing
    ones keep decoding."""

    def __init__(self, model: Model, params, max_batch: int = 4, max_len: int = 256,
                 metrics: Optional[MetricsRegistry] = None):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        # serving telemetry goes through the fabric registry (docs/scaling.md
        # "Serving tier"); the per-Request timestamps stay as raw material
        self.metrics = metrics if metrics is not None else MetricsRegistry()

        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))
        self._insert = jax.jit(kv_cache.insert_sequence, static_argnums=(2,))

        cache, _ = model.init_cache(max_batch, max_len)
        self.cache = cache
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        self.pending: List[Request] = []
        self._lock = threading.Lock()
        self._alive = False
        self.steps = 0

    # -- client API -------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 16, eos_id: int = -1) -> Request:
        req = Request(np.asarray(prompt, np.int32), max_new_tokens, eos_id)
        with self._lock:
            self.pending.append(req)
        return req

    def generate(self, prompt, max_new_tokens: int = 16, timeout: float = 120.0) -> List[int]:
        req = self.submit(prompt, max_new_tokens)
        if not self._alive:
            self.run_until_drained()
        if not req.done.wait(timeout):
            raise TimeoutError(req.request_id)
        return req.tokens

    # -- engine loop -----------------------------------------------------------
    def _admit(self) -> None:
        with self._lock:
            for slot in range(self.max_batch):
                if self.slot_req[slot] is not None or not self.pending:
                    continue
                req = self.pending.pop(0)
                batch = {"tokens": req.prompt[None, :]}
                if self.cfg.family == "encdec":
                    batch["frames"] = np.zeros(
                        (1, self.cfg.enc_seq, self.cfg.d_model), np.float32
                    )
                logits, seq_cache = self._prefill(self.params, batch)
                first = int(jnp.argmax(logits[0]))
                self.cache = self._insert(self.cache, seq_cache, slot)
                req.tokens.append(first)
                req.first_token_at = time.monotonic()
                self.metrics.histogram("serving.ttft_s").observe(
                    req.first_token_at - req.submitted
                )
                self.metrics.counter("serving.tokens_generated").inc()
                self.slot_req[slot] = req
                self.slot_pos[slot] = len(req.prompt)
                self._finish_if_done(slot)

    def _finish_if_done(self, slot: int) -> None:
        req = self.slot_req[slot]
        if req is None:
            return
        hit_eos = req.tokens and req.tokens[-1] == req.eos_id
        full = self.slot_pos[slot] >= self.max_len - 1
        if len(req.tokens) >= req.max_new_tokens or hit_eos or full:
            req.finished_at = time.monotonic()
            req.done.set()
            self.slot_req[slot] = None

    def _step(self) -> bool:
        """One decode step over all active slots (vector positions: each slot
        reads/writes its own cache position). Returns True if any active."""
        active = [s for s in range(self.max_batch) if self.slot_req[s] is not None]
        if not active:
            return False
        tok = np.zeros((self.max_batch, 1), np.int32)
        for s in active:
            tok[s, 0] = self.slot_req[s].tokens[-1]
        pos_vec = jnp.asarray(self.slot_pos)
        logits, self.cache = self._decode(self.params, jnp.asarray(tok), self.cache, pos_vec)
        nt = np.asarray(jnp.argmax(logits, axis=-1))  # greedy sampling
        for s in active:
            self.slot_req[s].tokens.append(int(nt[s]))
            self.slot_pos[s] += 1
            self._finish_if_done(s)
        self.steps += 1
        self.metrics.counter("serving.tokens_generated").inc(len(active))
        self.metrics.counter("serving.decode_batches").inc()
        self.metrics.gauge("serving.batch_occupancy").set(len(active))
        return True

    def serve_forever(self, stop_event: threading.Event, idle_sleep_s: float = 0.002) -> None:
        """Drive admit/decode until `stop_event` is set (for request streams
        that trickle in — run_until_drained exits between waves)."""
        self._alive = True
        try:
            while not stop_event.is_set():
                self._admit()
                if not self._step():
                    time.sleep(idle_sleep_s)
        finally:
            self._alive = False

    def run_until_drained(self, timeout: float = 300.0) -> None:
        t0 = time.monotonic()
        self._alive = True
        try:
            while time.monotonic() - t0 < timeout:
                self._admit()
                if not self._step():
                    with self._lock:
                        if not self.pending:
                            return
        finally:
            self._alive = False

    def stats(self) -> dict:
        return {
            "steps": self.steps,
            "active": sum(r is not None for r in self.slot_req),
            "pending": len(self.pending),
            "cache": kv_cache.summarize(self.cfg, self.max_batch, self.max_len),
        }
