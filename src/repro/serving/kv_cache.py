"""KV-cache utilities: sizing, slot insertion for continuous batching."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def cache_bytes(cfg: ModelConfig, batch: int, seq_len: int) -> int:
    """Analytical decode-state footprint (bytes) — the serving-capacity
    planner for admission control and the roofline memory term."""
    itemsize = jnp.dtype(cfg.dtype).itemsize
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.mla is not None:
            per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
        else:
            per_tok = 2 * cfg.n_kv_heads * cfg.hd
        total = cfg.n_layers * batch * seq_len * per_tok * itemsize
        if cfg.family == "encdec":
            total += cfg.n_layers * batch * cfg.enc_seq * 2 * cfg.n_kv_heads * cfg.hd * itemsize
        return total
    if cfg.family == "encdec":
        per_tok = 2 * cfg.n_kv_heads * cfg.hd
        return cfg.n_layers * batch * (seq_len + cfg.enc_seq) * per_tok * itemsize
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    H = s.n_heads(cfg.d_model)
    conv = (s.conv_kernel - 1) * (d_in + 2 * s.n_groups * s.d_state) * itemsize
    ssm = H * s.head_dim * s.d_state * 4  # fp32 state
    per_layer = (conv + ssm) * batch
    if cfg.family == "ssm":
        return cfg.n_layers * per_layer
    # hybrid: mamba states + shared-attn KV per group
    G = cfg.n_layers // cfg.shared_attn_every
    attn = G * batch * seq_len * 2 * cfg.n_kv_heads * cfg.hd * itemsize
    return cfg.n_layers * per_layer + attn


def insert_sequence(batched_cache: Any, seq_cache: Any, slot: int, batch_axis: int = 1) -> Any:
    """Place a single-sequence cache (batch dim 1) into slot `slot` of a
    batched cache. Caches are stacked over layers on axis 0, so the batch
    axis is 1 by convention."""

    def put(dst, src):
        idx = [slice(None)] * dst.ndim
        idx[batch_axis] = slice(slot, slot + 1)
        # pad/trim src seq dims up to dst
        pads = []
        for d in range(src.ndim):
            if d == batch_axis or src.shape[d] == dst.shape[d]:
                pads.append((0, 0))
            else:
                pads.append((0, dst.shape[d] - src.shape[d]))
        src = jnp.pad(src, pads)
        return dst.at[tuple(idx)].set(src.astype(dst.dtype))

    return jax.tree.map(put, batched_cache, seq_cache)


def summarize(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    b = cache_bytes(cfg, batch, seq_len)
    return {
        "bytes": int(b),
        "gib": round(b / 2**30, 3),
        "bytes_per_seq": int(b / max(batch, 1)),
    }
