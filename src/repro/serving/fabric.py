"""Fabric-served inference: the serving tier meets the FaaS tiers.

The paper's DLHub case study (§7) serves ML models through the fabric; this
module makes the in-repo jax models first-class fabric workloads. Model
steps are *registered functions* carrying ``ResourceSpec(capabilities=
{"jit"})`` so routing only lands them on jit-capable container pools, and
three pieces make serving fast through the task path:

- **Session-sticky KV-cache affinity** — every task of a generation session
  carries a ``session_id``; the Forwarder's :class:`SessionRouter` pins the
  session to the endpoint holding its KV-cache slot. On endpoint death the
  binding is evicted, the next decode step lands on a survivor, and the
  :class:`ModelHost` there rebuilds the cache from the token history carried
  in the request (`serving.cache_migrations`).
- **Endpoint-level continuous batching** — concurrent decode-step tasks for
  the same model meet in a :class:`DecodeCoalescer` (the interchange tier's
  ``BatchCoalescer`` generalized from task frames to kernel batches): the
  first arrival leads, waits a bounded window for peers, and runs ONE
  batched ``decode_step`` over the shared stacked cache; followers just
  collect their token.
- **cache_bytes admission control** — a host's slot count derives from
  :func:`repro.serving.kv_cache.cache_bytes`; prefill beyond it raises
  :class:`CacheAdmissionError` instead of silently growing decode state.

Hosts are *site state*: the serving functions are registered once and
``site_aware`` metadata hands them the executing endpoint's
:class:`~repro.core.worker.SiteRuntime`, where each endpoint lazily builds
its own :class:`ModelHost` (params shared in-process; a real deployment
loads per site). See docs/serving.md.
"""
from __future__ import annotations

import threading
import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.containers import ResourceSpec
from ..models.model import Model
from . import kv_cache

# Families whose decode state is positionally idempotent: re-running a step
# for a slot at an unchanged position rewrites the same K/V rows with the
# same values, so slots *absent* from a merged kernel invocation are
# unharmed. Recurrent state (ssm/hybrid) accumulates per step and would be
# corrupted, so those families serve unbatched (per-session caches).
_BATCHABLE_FAMILIES = ("dense", "moe")


class CacheAdmissionError(RuntimeError):
    """No free KV-cache slot under the host's ``cache_bytes`` budget."""


# ---------------------------------------------------------------------------
# decode coalescer
# ---------------------------------------------------------------------------
class _PendingDecode:
    __slots__ = ("token", "error", "event")

    def __init__(self):
        self.token: Optional[int] = None
        self.error: Optional[BaseException] = None
        self.event = threading.Event()


class DecodeCoalescer:
    """Merge concurrent decode-step calls into one batched kernel invocation.

    The interchange tier's ``BatchCoalescer`` generalized to kernel batches:
    instead of a pump thread flushing task frames on size/deadline, the
    *callers themselves* combine — the first arrival becomes the leader,
    waits up to ``window_s`` for more slots to join (stopping early once
    every currently-active session has arrived), then runs ``step_fn`` over
    the merged slot set while followers block on their own result. Exactly
    one kernel invocation serves the whole batch.
    """

    def __init__(
        self,
        step_fn: Callable[[List[int]], Dict[int, int]],
        window_s: float = 0.003,
        target_fn: Optional[Callable[[], int]] = None,
    ):
        self._step = step_fn
        self.window_s = window_s
        self._target = target_fn or (lambda: 1)
        self._cond = threading.Condition()
        self._waiting: Dict[int, _PendingDecode] = {}
        self._leading = False
        self.batches = 0
        self.merged = 0

    def submit(self, slot: int) -> int:
        mine = _PendingDecode()
        with self._cond:
            self._waiting[slot] = mine
            self._cond.notify_all()
            # follower path: somebody is already leading — wait for them to
            # take (and serve) our slot, or for leadership to free up
            while self._leading and not mine.event.is_set():
                self._cond.wait(timeout=self.window_s)
            if mine.event.is_set():
                return self._collect(mine)
            self._leading = True
        try:
            deadline = time.monotonic() + self.window_s
            with self._cond:
                while (
                    len(self._waiting) < max(1, self._target())
                    and (remaining := deadline - time.monotonic()) > 0
                ):
                    self._cond.wait(timeout=remaining)
                batch = dict(self._waiting)
                self._waiting.clear()
            try:
                tokens = self._step(sorted(batch))
            except BaseException as exc:  # noqa: BLE001 — fan out, don't hang peers
                with self._cond:
                    for pending in batch.values():
                        pending.error = exc
                        pending.event.set()
                    self._cond.notify_all()
                raise
            with self._cond:
                self.batches += 1
                self.merged += len(batch)
                for s, pending in batch.items():
                    pending.token = tokens[s]
                    pending.event.set()
                self._cond.notify_all()
        finally:
            with self._cond:
                self._leading = False
                self._cond.notify_all()
        return self._collect(mine)

    @staticmethod
    def _collect(pending: _PendingDecode) -> int:
        if pending.error is not None:
            raise pending.error
        assert pending.token is not None
        return pending.token


# ---------------------------------------------------------------------------
# per-endpoint model host
# ---------------------------------------------------------------------------
@dataclass
class _SessionState:
    slot: int
    pos: int                      # next cache write position
    last: int                     # last accepted token (decode input)
    cache: Any = None             # unbatched mode: private batch-1 cache
    touched: float = field(default_factory=time.monotonic)


class ModelHost:
    """One endpoint's serving state for one model: params, slotted KV cache,
    session table, and the decode coalescer.

    ``batching=True`` (attention families) keeps ONE stacked cache of
    ``n_slots`` sequences — prefills insert into free slots, concurrent
    decode steps coalesce into one batched kernel. Other families (or
    ``batching=False``, the per-request baseline) give each session a
    private batch-1 cache and run one kernel per request, serialized like
    independent device programs.
    """

    def __init__(
        self,
        model: Model,
        params,
        max_len: int = 96,
        max_sessions: int = 8,
        cache_bytes_budget: Optional[int] = None,
        batching: bool = True,
        window_s: float = 0.003,
        metrics=None,
    ):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.max_len = max_len
        if batching and self.cfg.family not in _BATCHABLE_FAMILIES:
            batching = False
        self.batching = batching
        # admission control: slots the cache_bytes budget affords
        per_seq = kv_cache.cache_bytes(self.cfg, 1, max_len)
        if cache_bytes_budget is not None:
            max_sessions = max(1, min(max_sessions, cache_bytes_budget // per_seq))
        self.n_slots = int(max_sessions)
        self.metrics = metrics
        if metrics is not None:
            metrics.gauge("serving.cache_bytes").set(
                kv_cache.cache_bytes(self.cfg, self.n_slots, max_len)
            )

        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))
        self._insert = jax.jit(kv_cache.insert_sequence, static_argnums=(2,))

        self._lock = threading.Lock()
        self.sessions: Dict[str, _SessionState] = {}
        self._free = set(range(self.n_slots))
        if batching:
            self.cache, _ = model.init_cache(self.n_slots, max_len)
            self.slot_pos = np.zeros(self.n_slots, np.int32)
            self.slot_last = np.zeros(self.n_slots, np.int32)
            self.coalescer = DecodeCoalescer(
                self._batched_step,
                window_s=window_s,
                target_fn=lambda: len(self.sessions),
            )
        else:
            self.coalescer = None

    # -- metrics helpers ---------------------------------------------------
    def _count(self, name: str, n: int = 1) -> None:
        if self.metrics is not None:
            self.metrics.counter(name).inc(n)

    # -- session lifecycle -------------------------------------------------
    def prefill(self, session: str, tokens) -> int:
        """Open (or rebuild) `session` from its full token history; returns
        the next predicted token. Raises CacheAdmissionError when every slot
        under the cache_bytes budget is taken."""
        tokens = np.asarray(tokens, np.int32)
        if len(tokens) >= self.max_len:
            raise ValueError(
                f"session {session}: {len(tokens)} tokens >= max_len {self.max_len}"
            )
        with self._lock:
            old = self.sessions.pop(session, None)
            if old is not None:
                self._free.add(old.slot)
            if not self._free:
                self._count("serving.admission_rejects")
                raise CacheAdmissionError(
                    f"model host full: {self.n_slots} KV slots "
                    f"({kv_cache.cache_bytes(self.cfg, self.n_slots, self.max_len)} "
                    f"bytes) all serving sessions"
                )
            slot = self._free.pop()
        batch = {"tokens": tokens[None, :]}
        if self.cfg.family == "encdec":
            batch["frames"] = np.zeros(
                (1, self.cfg.enc_seq, self.cfg.d_model), np.float32
            )
        logits, seq_cache = self._prefill(self.params, batch)
        first = int(jnp.argmax(logits[0]))
        with self._lock:
            if self.batching:
                self.cache = self._insert(self.cache, seq_cache, slot)
                self.slot_pos[slot] = len(tokens)
                self.slot_last[slot] = first
                seq_cache = None
            self.sessions[session] = _SessionState(
                slot=slot, pos=len(tokens), last=first, cache=seq_cache
            )
            n_active = len(self.sessions)
        self._count("serving.prefills")
        self._count("serving.tokens_generated")
        if self.metrics is not None:
            self.metrics.gauge("serving.sessions_active").set(n_active)
        return first

    def decode(self, session: str, tokens) -> Tuple[int, bool]:
        """One decode step for `session`; returns ``(next_token, migrated)``.

        A hit (`serving.affinity_hits`) runs against the resident cache slot;
        a miss means the session's home died and sticky routing moved it here
        — the cache is rebuilt from the full token history (`tokens`), which
        is the explicit re-prefill migration path.
        """
        with self._lock:
            st = self.sessions.get(session)
        if st is None:
            self._count("serving.cache_migrations")
            return self.prefill(session, tokens), True
        self._count("serving.affinity_hits")
        if self.batching:
            nxt = self.coalescer.submit(st.slot)
        else:
            with self._lock:  # per-request baseline: one kernel per request
                tok = jnp.asarray([[st.last]], jnp.int32)
                pos = jnp.asarray([st.pos], jnp.int32)
                logits, st.cache = self._decode(self.params, tok, st.cache, pos)
                nxt = int(jnp.argmax(logits[0]))
                st.pos += 1
        with self._lock:
            st.last = nxt
            st.touched = time.monotonic()
        self._count("serving.tokens_generated")
        return nxt, False

    def release(self, session: str) -> bool:
        with self._lock:
            st = self.sessions.pop(session, None)
            if st is not None:
                self._free.add(st.slot)
            n_active = len(self.sessions)
        if self.metrics is not None:
            self.metrics.gauge("serving.sessions_active").set(n_active)
        return st is not None

    # -- batched decode kernel --------------------------------------------
    def _batched_step(self, slots: List[int]) -> Dict[int, int]:
        """One decode kernel over the shared stacked cache serving `slots`.

        Every slot's row advances at its own position (vector pos); slots
        not in `slots` rewrite their current position with their last token
        — byte-identical values their own next step overwrites again, which
        is why batching is gated to attention families.
        """
        with self._lock:
            tok = self.slot_last[:, None].copy()
            pos_vec = jnp.asarray(self.slot_pos)
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tok), self.cache, pos_vec
            )
            nt = np.asarray(jnp.argmax(logits, axis=-1))
            out = {}
            for s in slots:
                self.slot_last[s] = int(nt[s])
                self.slot_pos[s] += 1
                out[s] = int(nt[s])
        self._count("serving.decode_batches")
        if self.metrics is not None:
            self.metrics.gauge("serving.batch_occupancy").set(len(slots))
            self.metrics.histogram("serving.merged_per_step").observe(len(slots))
        return out

    def stats(self) -> dict:
        with self._lock:
            return {
                "batching": self.batching,
                "slots": self.n_slots,
                "active": len(self.sessions),
                "free": len(self._free),
                "decode_batches": self.coalescer.batches if self.coalescer else 0,
                "merged": self.coalescer.merged if self.coalescer else 0,
                "cache": kv_cache.summarize(self.cfg, self.n_slots, self.max_len),
            }


# ---------------------------------------------------------------------------
# registration: model specs + per-site hosts
# ---------------------------------------------------------------------------
@dataclass
class ModelServeSpec:
    """Blueprint a site builds its ModelHost from (in-process the params are
    shared; a real deployment loads them per endpoint)."""

    name: str
    model: Model
    params: Any
    max_len: int
    max_sessions: int
    cache_bytes_budget: Optional[int]
    batching: bool
    window_s: float


_SPECS: Dict[str, ModelServeSpec] = {}
_SPECS_LOCK = threading.Lock()


def _host_for(site, name: str) -> ModelHost:
    with _SPECS_LOCK:
        spec = _SPECS.get(name)
    if spec is None:
        raise KeyError(f"model {name!r} not served (serve_model first)")

    def build() -> ModelHost:
        return ModelHost(
            spec.model,
            spec.params,
            max_len=spec.max_len,
            max_sessions=spec.max_sessions,
            cache_bytes_budget=spec.cache_bytes_budget,
            batching=spec.batching,
            window_s=spec.window_s,
            metrics=site.metrics,
        )

    return site.get_or_create(("serving-host", name), build)


def reset_serving() -> None:
    """Drop every served-model spec (tests/benchmarks hygiene; hosts live in
    their endpoints' SiteRuntimes and die with them)."""
    with _SPECS_LOCK:
        _SPECS.clear()


# the three serving functions: module-level so registration is idempotent
# (same content hash) no matter how many models/services register them
def _serve_prefill(doc, site):
    host = _host_for(site, doc["model"])
    token = host.prefill(doc["session"], doc["tokens"])
    return {"token": token, "endpoint": site.endpoint_id, "migrated": False}


def _serve_decode(doc, site):
    host = _host_for(site, doc["model"])
    token, migrated = host.decode(doc["session"], doc["tokens"])
    return {"token": token, "endpoint": site.endpoint_id, "migrated": migrated}


def _serve_release(doc, site):
    host = _host_for(site, doc["model"])
    return host.release(doc["session"])


def serve_model(
    service,
    model: Model,
    params,
    name: str,
    max_len: int = 96,
    max_sessions: int = 8,
    cache_bytes_budget: Optional[int] = None,
    batching: bool = True,
    window_s: float = 0.003,
    token=None,
) -> "ServingClient":
    """Register `model` as a fabric-served inference workload.

    Registers prefill/decode/release as public fabric functions requiring
    the ``jit`` capability and records the host blueprint every jit-capable
    endpoint builds lazily on first task. Returns a :class:`ServingClient`
    bound to this service.
    """
    spec = ModelServeSpec(
        name=name,
        model=model,
        params=params,
        max_len=max_len,
        max_sessions=max_sessions,
        cache_bytes_budget=cache_bytes_budget,
        batching=batching,
        window_s=window_s,
    )
    with _SPECS_LOCK:
        _SPECS[name] = spec
    requirements = ResourceSpec(capabilities=frozenset({"jit"}))
    common = dict(
        public=True, requirements=requirements, token=token,
        site_aware=True, serialize_result=False,
    )
    fids = {
        "prefill": service.register_function(
            _serve_prefill, name="serving/prefill",
            description="prefill-into-slot for served models",
            **common,
        ),
        "decode": service.register_function(
            _serve_decode, name="serving/decode_step",
            description="coalesced decode step for served models", **common,
        ),
        "release": service.register_function(
            _serve_release, name="serving/release",
            description="free a session's KV-cache slot", **common,
        ),
    }
    return ServingClient(service, name, fids, max_len=max_len, token=token)


# ---------------------------------------------------------------------------
# client surface
# ---------------------------------------------------------------------------
class ServeSession:
    """One sticky generation session: every step routes with the same
    ``session_id`` so the Forwarder pins it to the endpoint holding its
    KV-cache slot."""

    def __init__(self, client: "ServingClient", session_id: str,
                 history: List[int], first_token: int, endpoint: str,
                 ttft_s: float):
        self._client = client
        self.session_id = session_id
        self.history = history          # prompt + every generated token
        self.tokens = [first_token]     # generated tokens only
        self.endpoints = [endpoint]     # serving endpoint per step
        self.migrations = 0
        self.ttft_s = ttft_s
        self.closed = False

    def step(self, timeout: float = 60.0) -> int:
        """One decode step (one fabric task). The full token history rides
        along so a failed-over session can re-prefill on its new endpoint."""
        out = self._client._call(
            "decode",
            {"session": self.session_id, "tokens": list(self.history)},
            session_id=self.session_id,
            timeout=timeout,
        )
        self.history.append(out["token"])
        self.tokens.append(out["token"])
        self.endpoints.append(out["endpoint"])
        self.migrations += bool(out["migrated"])
        return out["token"]

    def stream(self, max_new_tokens: int, eos_id: int = -1,
               timeout: float = 60.0) -> Iterator[int]:
        """Yield generated tokens (including the prefill's first token)
        until `max_new_tokens`, EOS, or the host's context limit."""
        yield self.tokens[0]
        while (
            len(self.tokens) < max_new_tokens
            and self.tokens[-1] != eos_id
            and len(self.history) < self._client.max_len - 1
        ):
            yield self.step(timeout=timeout)

    def close(self, timeout: float = 30.0) -> None:
        if self.closed:
            return
        self.closed = True
        try:
            self._client._call(
                "release", {"session": self.session_id},
                session_id=self.session_id, timeout=timeout,
            )
        finally:
            sessions = getattr(self._client.service.forwarder, "sessions", None)
            if sessions is not None:
                sessions.forget(self.session_id)

    def __enter__(self) -> "ServeSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class ServingClient:
    """Client surface over a served model: open sticky sessions, stream
    tokens, observe TTFT through the fabric metrics."""

    def __init__(self, service, model_name: str, fids: Dict[str, str],
                 max_len: int, token=None):
        self.service = service
        self.model_name = model_name
        self.fids = fids
        self.max_len = max_len
        self.token = token

    def _call(self, which: str, doc: dict, session_id: Optional[str] = None,
              endpoint_id: Optional[str] = None, timeout: float = 60.0):
        doc = {"model": self.model_name, **doc}
        future = self.service.run(
            self.fids[which], doc,
            endpoint_id=endpoint_id, session_id=session_id,
            token=self.token,
        )
        return future.result(timeout)

    def session(self, prompt, session_id: Optional[str] = None,
                endpoint_id: Optional[str] = None, timeout: float = 60.0,
                admission_retries: int = 2) -> ServeSession:
        """Prefill `prompt` into a slot somewhere and return the sticky
        session. A CacheAdmissionError (endpoint full under its cache_bytes
        budget) forgets the binding and retries, letting the policy place
        the session on an endpoint with free slots."""
        session_id = session_id or f"s-{uuid.uuid4().hex[:12]}"
        prompt = [int(t) for t in np.asarray(prompt).reshape(-1)]
        t0 = time.monotonic()
        attempt = 0
        while True:
            try:
                out = self._call(
                    "prefill", {"session": session_id, "tokens": prompt},
                    session_id=session_id, endpoint_id=endpoint_id,
                    timeout=timeout,
                )
                break
            except CacheAdmissionError:
                attempt += 1
                sessions = getattr(self.service.forwarder, "sessions", None)
                if sessions is not None:
                    sessions.forget(session_id)
                if attempt > admission_retries:
                    raise
        ttft = time.monotonic() - t0
        self.service.metrics.histogram("serving.ttft_s").observe(ttft)
        return ServeSession(
            self, session_id, history=prompt + [out["token"]],
            first_token=out["token"], endpoint=out["endpoint"], ttft_s=ttft,
        )

    def generate(self, prompt, max_new_tokens: int = 16, eos_id: int = -1,
                 timeout: float = 60.0) -> List[int]:
        with self.session(prompt, timeout=timeout) as s:
            return list(s.stream(max_new_tokens, eos_id=eos_id, timeout=timeout))
