#!/usr/bin/env python
"""Docs honesty checks (run by CI's docs job).

1. Every relative markdown link in README.md, docs/*.md, and
   examples/README.md must resolve to an existing file (anchors stripped).
2. Every metric name cataloged in docs/scaling.md (backticked
   ``tier.metric_name`` tokens under the known tier prefixes) must appear
   literally somewhere in src/ — the catalog can't drift from the code.

Exit status 0 on success; 1 with a per-failure report otherwise.
Stdlib only:  python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = sorted(
    [REPO / "README.md", REPO / "examples" / "README.md"]
    + list((REPO / "docs").glob("*.md"))
)
METRIC_PREFIXES = (
    "service.", "forwarder.", "endpoint.", "executor.", "warming.",
    "autoscaler.", "workflow.", "trigger.", "container.", "journal.",
    "data.", "predictor.", "fair.", "serving.",
)

# [text](target) — excluding images; target split from any #anchor / title
LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
METRIC_RE = re.compile(r"`([a-z_]+\.[a-z0-9_]+)`")


def check_links() -> list[str]:
    failures = []
    for doc in DOC_FILES:
        if not doc.exists():
            failures.append(f"{doc.relative_to(REPO)}: file missing")
            continue
        for m in LINK_RE.finditer(doc.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:  # pure in-page anchor
                continue
            resolved = (doc.parent / path).resolve()
            if not resolved.exists():
                failures.append(
                    f"{doc.relative_to(REPO)}: broken link -> {target}"
                )
    return failures


def check_metrics_catalog() -> list[str]:
    catalog = REPO / "docs" / "scaling.md"
    if not catalog.exists():
        return ["docs/scaling.md missing (metrics catalog)"]
    names = {
        m.group(1)
        for m in METRIC_RE.finditer(catalog.read_text())
        if m.group(1).startswith(METRIC_PREFIXES)
    }
    if not names:
        return ["docs/scaling.md lists no metric names — catalog gutted?"]
    src_blob = "\n".join(
        p.read_text() for p in (REPO / "src").rglob("*.py")
    )
    return [
        f"docs/scaling.md: metric `{name}` not found anywhere in src/"
        for name in sorted(names)
        if name not in src_blob
    ]


def main() -> int:
    failures = check_links() + check_metrics_catalog()
    if failures:
        print(f"{len(failures)} docs check failure(s):")
        for f in failures:
            print(f"  - {f}")
        return 1
    n_links = sum(
        len(LINK_RE.findall(d.read_text())) for d in DOC_FILES if d.exists()
    )
    print(f"docs checks passed: {len(DOC_FILES)} files, {n_links} links, "
          f"metrics catalog consistent with src/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
